"""Load sweep: offered load x arrival process x policy x device count.

The ROADMAP regime the paper never evaluates: *sustained* open-loop
traffic.  For each (arrival process, policy, n_devices) curve the sweep
drives the cluster simulator with the traffic subsystem
(``repro.workloads``) at increasing offered load — expressed as a fraction
of aggregate cluster capacity, ``rate = load x n_devices / E[isolated
time]`` — and reports the latency–throughput curve plus the **SLA knee**:
the highest offered load whose SLA satisfaction (per-task ``sla_scale``
targets) still clears ``SLA_KNEE_TARGET``.

Per point: achieved throughput (tasks/s), goodput (SLA-meeting tasks/s),
p95/p99 NTT and turnaround, SLA satisfaction, and mean utilization.

Usage::

    PYTHONPATH=src python benchmarks/load_sweep.py            # full sweep
    PYTHONPATH=src python benchmarks/load_sweep.py --smoke    # CI-sized
    PYTHONPATH=src python benchmarks/load_sweep.py --seed 7   # re-based RNG
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

# allow `python benchmarks/load_sweep.py` from anywhere (cluster_scaling
# does the same): make both `benchmarks` and `repro` importable
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks import common
from repro.core import metrics
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.scheduler import make_policy
from repro.hw import PAPER_NPU
from repro.workloads import MMPP, Poisson, generate, paper_mix

ARRIVAL_KINDS = ("poisson", "mmpp")
POLICIES = ("fcfs", "prema")
DEVICE_COUNTS = (1, 4)
LOADS = (0.2, 0.4, 0.6, 0.8, 1.0, 1.2)
SLA_KNEE_TARGET = 0.9
TASKS_PER_DEVICE = 24

_mean_isolated: Dict[int, float] = {}    # keyed by BASE_SEED


def mean_isolated_time(n_probe: int = 64) -> float:
    """E[isolated time] of the paper mix — converts an offered-load
    fraction into an arrival rate."""
    key = common.BASE_SEED
    if key not in _mean_isolated:
        tr = generate(paper_mix(), common.rng(8400), n_probe,
                      pred=common.predictor())
        _mean_isolated[key] = float(
            np.mean([t.isolated_time for t in tr.tasks()]))
    return _mean_isolated[key]


def make_process(kind: str, rate: float):
    if kind == "poisson":
        return Poisson(rate=rate)
    if kind == "mmpp":
        return MMPP.bursty(rate, duty=0.3)
    raise KeyError(f"unknown arrival kind {kind!r}")


def run_point(kind: str, policy: str, n_devices: int, load: float,
              n_tasks: int, n_runs: int, seed0: int = 8500
              ) -> Dict[str, float]:
    rate = load * n_devices / mean_isolated_time()
    runs = []
    for r in range(n_runs):
        rng = common.rng(seed0 + 97 * r)
        tr = generate(paper_mix(arrivals=make_process(kind, rate)), rng,
                      n_tasks, pred=common.predictor())
        sim = ClusterSimulator(
            PAPER_NPU, make_policy(policy, preemptive=True),
            ClusterConfig(mechanism="dynamic", n_devices=n_devices,
                          placement="least_loaded"))
        sim.run(tr)
        runs.append(sim.summary())
    return metrics.aggregate(runs)


def find_knee(points: Sequence[Tuple[float, Dict[str, float]]],
              target: float = SLA_KNEE_TARGET) -> float:
    """Highest offered load whose SLA satisfaction still clears ``target``
    (0 when even the lightest load misses it)."""
    knee = 0.0
    for load, m in sorted(points, key=lambda p: p[0]):
        if m["sla_satisfaction"] >= target:
            knee = load
    return knee


def sweep(kinds: Sequence[str], policies: Sequence[str],
          device_counts: Sequence[int], loads: Sequence[float],
          n_runs: int, tasks_per_device: int = TASKS_PER_DEVICE
          ) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    for kind in kinds:
        for pol in policies:
            for nd in device_counts:
                curve = []
                for load in loads:
                    t0 = time.perf_counter()
                    m = run_point(kind, pol, nd, load,
                                  n_tasks=tasks_per_device * nd,
                                  n_runs=n_runs)
                    us = (time.perf_counter() - t0) / n_runs * 1e6
                    curve.append((load, m))
                    tag = f"load_sweep.{kind}.{pol}.d{nd}.load{load:g}"
                    rows.append((tag, us, (
                        f"tput={m['throughput']:.1f};"
                        f"goodput={m['goodput']:.1f};"
                        f"p95_ntt={m['p95_ntt']:.2f};"
                        f"p99_ntt={m['p99_ntt']:.2f};"
                        f"p99_tat={m['p99_turnaround']*1e3:.1f}ms;"
                        f"sla={m['sla_satisfaction']:.3f};"
                        f"util={m['util_mean']:.3f}")))
                knee = find_knee(curve)
                rows.append((f"load_sweep.{kind}.{pol}.d{nd}.sla_knee",
                             0.0, f"load={knee:g}@sla>={SLA_KNEE_TARGET}"))
    return rows


def run(smoke: bool = False) -> List[Tuple[str, float, str]]:
    """Entry point for benchmarks/run.py (full sweep) and --smoke (CI)."""
    if smoke:
        return sweep(ARRIVAL_KINDS, POLICIES, DEVICE_COUNTS,
                     loads=(0.6, 1.2), n_runs=1, tasks_per_device=8)
    return sweep(ARRIVAL_KINDS, POLICIES, DEVICE_COUNTS, LOADS, n_runs=3)


def showcase_cell(n_devices: int = 4, load: float = 1.2):
    """The past-saturation mmpp/prema cell, for ``--trace-out``."""
    rate = load * n_devices / mean_isolated_time()
    tr = generate(paper_mix(arrivals=make_process("mmpp", rate)),
                  common.rng(8500), TASKS_PER_DEVICE * n_devices,
                  pred=common.predictor())
    sim = ClusterSimulator(
        PAPER_NPU, make_policy("prema", preemptive=True),
        ClusterConfig(mechanism="dynamic", n_devices=n_devices,
                      placement="least_loaded"))
    return sim, tr.tasks()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (2 loads, 1 run per point)")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base every benchmark RNG stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write machine-readable JSON results")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land next to --out")
    common.add_obs_args(ap)
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    with common.maybe_profile(args.profile, args.out, "load_sweep"):
        rows = run(smoke=args.smoke)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "load_sweep", rows)
    common.record_showcase(args, showcase_cell,
                           window=2.0 * mean_isolated_time())


if __name__ == "__main__":
    main()
