"""Predictor sweep: how much prediction error each predictive controller
tolerates before it stops paying for itself.

Three controllers consume ``Task.predicted_total`` (installed through the
``RuntimePredictor`` API, ``repro/core/predictor.py``); this sweep injects
controlled multiplicative error with ``NoisyPredictor`` (lognormal,
mean-unbiased, per-task deterministic) and measures each controller
against its prediction-free baseline at identical offered load:

* ``admission``   ``PredictedCostBucket`` (meters admitted *predicted
  work*) vs a request-count ``TokenBucket`` at the same sustained budget,
  under 2x overload.  Cost-aware admission packs more small requests into
  the same work budget — until mispredictions let oversized work through.
* ``autoscale``   the lookahead autoscaler (extrapolates predicted
  arriving work ``lookahead`` seconds ahead) vs the reactive queue-depth
  scaler on a diurnal ramp with non-zero provision latency.  Forecasts
  average many tasks, so unbiased noise mostly washes out — the
  interesting output is the zero-error gate: SLA >= reactive at <= its
  device-seconds.
* ``backfill``    the EASY ``Backfill`` policy (runs batch work that fits
  the predicted gap before the next interactive arrival) vs conservative
  reservation (``conservative=True``) and gap-blind HPF (``greedy``), on
  a single device with a batch backlog pierced by strictly periodic
  interactive arrivals.  Underestimates start batch work that overruns
  the reservation (interactive SLA drops); overestimates hold the device
  idle (batch throughput drops).

Per error level the sweep emits one row per controller variant; the
``predictor.break.*`` rows report the first error level at which the
controller loses to its baseline (``knee=2.0`` = never, within the swept
grid).  ``benchmarks/check_smoke.py`` gates the zero-error columns: exact
predictions must beat every baseline (and the autoscaler must dominate
reactive on *both* SLA and device-seconds).

Usage::

    PYTHONPATH=src python benchmarks/predictor_sweep.py            # full
    PYTHONPATH=src python benchmarks/predictor_sweep.py --smoke    # CI
    PYTHONPATH=src python benchmarks/predictor_sweep.py --out a.json
"""
from __future__ import annotations

import argparse
import math
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np

from benchmarks import common
from benchmarks.overload_sweep import HI_TENANT, mean_isolated_time, tenant_mix
from repro.core import metrics
from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.predictor import (AnalyticalRuntime, NoisyPredictor,
                                  apply_runtime_predictor)
from repro.core.scheduler import Backfill, make_policy
from repro.core.task import Task, TaskState
from repro.configs import paper_workloads as pw
from repro.hw import PAPER_NPU
from repro.workloads import (Diurnal, Poisson, TenantSpec, TrafficMix,
                             generate)
from repro.workloads.admission import PredictedCostBucket, TokenBucket

ERRORS = (0.0, 0.15, 0.3, 0.6, 1.0)
SMOKE_ERRORS = (0.0, 0.6)
CONTROLLERS = ("admission", "autoscale", "backfill")
BREAK_NONE = 2.0            # sentinel: no break inside the swept grid
ADMIT_BUDGET = 0.75         # sustained admitted load, device capacities
MAX_DEVICES = 4
AVG_LOAD = 1.8              # mean offered load: peak 1.85x ~ fleet limit
PROVISION_LAT = 2.0         # device provision latency, mean isolated times
LOOKAHEAD = 3.0             # lookahead horizon, mean isolated times
TARGET_UTIL = 1.0           # lookahead sizing: forecast work / target util
SLA_SCALE = 1.5             # interactive SLA tightness (autoscale cell)


def noisy(tasks: Sequence[Task], error: float, seed: int) -> List[Task]:
    """Install the error-injected predictor (exact pass-through at 0)."""
    rp = NoisyPredictor(AnalyticalRuntime(), error=error, seed=seed)
    return apply_runtime_predictor(tasks, rp)


# ---------------------------------------------------------------------------
# admission cell: predicted-work vs request-count metering under overload
# ---------------------------------------------------------------------------


def run_admission(variant: str, error: float, n_runs: int,
                  n_tasks: int) -> Dict[str, float]:
    iso = mean_isolated_time()
    runs = []
    for r in range(n_runs):
        rng = common.rng(9700 + 173 * r)
        tr = generate(tenant_mix(Poisson(rate=2.0 / iso)), rng, n_tasks,
                      pred=common.predictor())
        tasks = noisy(tr.tasks(), error, seed=37 + r)
        if variant == "predicted_cost":
            adm = PredictedCostBucket(rate=ADMIT_BUDGET, burst=4.0 * iso)
        else:
            adm = TokenBucket(rate=ADMIT_BUDGET / iso, burst=4.0)
        sim = ClusterSimulator(
            PAPER_NPU, make_policy("prema", preemptive=True),
            ClusterConfig(n_devices=1, mechanism="dynamic", admission=adm))
        done = sim.run(tasks)
        m = sim.summary()
        hi = metrics.per_tenant_summary(done).get(HI_TENANT, {})
        shed = sum(t.state == TaskState.DROPPED for t in done) / len(done)
        runs.append({
            "goodput": m["goodput"],
            "sla_satisfaction": m["sla_satisfaction"],
            "sla_hi": float(hi.get("sla_satisfaction", float("nan"))),
            "shed_frac": shed,
            "p99_ntt": m["p99_ntt"],
        })
    return metrics.aggregate(runs)


# ---------------------------------------------------------------------------
# autoscale cell: lookahead vs reactive on the diurnal ramp
# ---------------------------------------------------------------------------


def tight_mix(arrivals) -> TrafficMix:
    """Three tenants with *tight* SLAs (interactive at ``SLA_SCALE`` x
    isolated time).  The overload cell's lenient mix would make minimal
    capacity SLA-optimal — here attainment genuinely depends on how fast
    the fleet tracks the diurnal ramp, which is what the lookahead gate
    measures."""
    models = tuple(pw.WORKLOAD_NAMES)
    s = SLA_SCALE
    return TrafficMix(tenants=(
        TenantSpec(name=HI_TENANT, models=models, share=0.25, priority=9,
                   sla_scale=s),
        TenantSpec(name="standard", models=models, share=0.375, priority=3,
                   sla_scale=2 * s),
        TenantSpec(name="batch", models=models, share=0.375, priority=1,
                   sla_scale=8 * s),
    ), arrivals=arrivals, kind="paper")


def run_autoscale(variant: str, error: float, n_runs: int,
                  n_tasks: int) -> Dict[str, float]:
    iso = mean_isolated_time()
    rate, period = AVG_LOAD / iso, 64.0 * iso
    runs = []
    for r in range(n_runs):
        rng = common.rng(9800 + 193 * r)
        tr = generate(
            tight_mix(Diurnal(base_rate=rate, amplitude=0.85, period=period,
                              phase=0.75)),
            rng, n_tasks, pred=common.predictor())
        tasks = noisy(tr.tasks(), error, seed=53 + r)
        sim = ClusterSimulator(
            PAPER_NPU, make_policy("prema", preemptive=True),
            ClusterConfig(n_devices=1, mechanism="dynamic",
                          provision_latency=PROVISION_LAT * iso))
        cfg = dict(min_devices=1, max_devices=MAX_DEVICES,
                   target_queue_per_device=1.0, low_watermark=0.1,
                   window=10.0 * iso, cooldown=2.5 * iso)
        if variant == "lookahead":
            cfg.update(lookahead=LOOKAHEAD * iso, target_util=TARGET_UTIL)
        scaler = Autoscaler(AutoscalerConfig(**cfg)).attach(sim, tasks=tasks)
        done = sim.run(tasks)
        m = sim.summary()
        hi = metrics.per_tenant_summary(done).get(HI_TENANT, {})
        runs.append({
            "sla_hi": float(hi.get("sla_satisfaction", float("nan"))),
            "sla_satisfaction": m["sla_satisfaction"],
            "device_seconds": m["capacity_seconds"],
            "p99_ntt": m["p99_ntt"],
            "n_scale_ups": m["n_scale_ups"],
            "n_scale_downs": m["n_scale_downs"],
        })
        scaler.detach()
    return metrics.aggregate(runs)


# ---------------------------------------------------------------------------
# backfill cell: EASY vs reservation vs gap-blind, one device
# ---------------------------------------------------------------------------

N_BATCH = 24
N_INTERACTIVE = 12


def backfill_workload(iso: float, seed: int) -> Tuple[List[Task], float]:
    """Batch backlog at t=0 plus strictly periodic interactive arrivals
    (period ``G``); returns (tasks, G).  Batch sizes straddle the gap so
    fitting is a real decision, not a foregone conclusion."""
    rng = common.rng(seed)
    gap = 4.0 * iso
    tasks = []

    def mk(tid, total, priority, arrival, tenant, sla_scale):
        n = 6
        return Task(tid=tid, model=f"m{tid % 4}", priority=priority,
                    arrival=arrival, batch=1,
                    node_times=np.full(n, total / n),
                    node_out_bytes=np.full(n, 1 << 17, dtype=np.int64),
                    predicted_total=total, tenant=tenant,
                    sla_scale=sla_scale)

    for i in range(N_BATCH):
        total = float(rng.uniform(1.5, 6.0)) * iso
        tasks.append(mk(i, total, 1, 0.0, "batch", 200.0))
    for k in range(N_INTERACTIVE):
        tasks.append(mk(N_BATCH + k, 0.5 * iso, 9, (k + 1) * gap,
                        HI_TENANT, 3.0))
    return tasks, gap


def exact_gap_fn(gap: float, last_arrival: float):
    """Time until the next scheduled interactive arrival (the reservation
    oracle — exact by construction in this synthetic cell)."""

    def fn(now: float) -> float:
        if now >= last_arrival:
            return math.inf
        k = math.floor(now / gap) + 1
        return k * gap - now

    return fn


def run_backfill(variant: str, error: float, n_runs: int,
                 _n_tasks: int) -> Dict[str, float]:
    iso = mean_isolated_time()
    runs = []
    for r in range(n_runs):
        tasks, gap = backfill_workload(iso, seed=9900 + 149 * r)
        tasks = noisy(tasks, error, seed=71 + r)
        if variant == "greedy":
            pol = make_policy("hpf", preemptive=False)
        else:
            pol = Backfill(preemptive=False,
                           conservative=(variant == "reserve"))
            pol.gap_fn = exact_gap_fn(gap, N_INTERACTIVE * gap)
        sim = ClusterSimulator(
            PAPER_NPU, pol, ClusterConfig(n_devices=1, mechanism="dynamic"))
        done = sim.run(tasks)
        m = sim.summary()
        makespan = max(t.completion for t in done)
        batch_work = sum(t.isolated_time for t in done if t.tenant == "batch")
        hi = [t for t in done if t.tenant == HI_TENANT]
        runs.append({
            "tput_batch": batch_work / makespan,
            "sla_hi": float(np.mean([t.sla_met() for t in hi])),
            "makespan": makespan,
            "p99_ntt": m["p99_ntt"],
        })
    return metrics.aggregate(runs)


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------

# per controller: (runner, error-consuming variant, baseline variants)
CELLS = {
    "admission": (run_admission, "predicted_cost", ("token_bucket",)),
    "autoscale": (run_autoscale, "lookahead", ("reactive",)),
    "backfill": (run_backfill, "backfill", ("reserve", "greedy")),
}


def healthy(controller: str, m: Dict[str, float],
            base: Dict[str, Dict[str, float]]) -> bool:
    """Does the predictive controller still beat its baseline here?"""
    if controller == "admission":
        return m["goodput"] >= base["token_bucket"]["goodput"]
    if controller == "autoscale":
        rm = base["reactive"]
        return (m["sla_satisfaction"] >= rm["sla_satisfaction"]
                and m["device_seconds"] <= rm["device_seconds"])
    rm = base["reserve"]
    return (m["tput_batch"] > rm["tput_batch"]
            and m["sla_hi"] >= rm["sla_hi"])


def derived_str(m: Dict[str, float]) -> str:
    keys = ("goodput", "sla_hi", "sla_satisfaction", "shed_frac",
            "device_seconds", "tput_batch", "p99_ntt")
    short = {"sla_satisfaction": "sla", "device_seconds": "devsec",
             "shed_frac": "shed", "p99_ntt": "p99_ntt"}
    parts = [f"{short.get(k, k)}={m[k]:.4f}" for k in keys if k in m]
    return ";".join(parts)


def sweep(errors: Sequence[float], n_runs: int, n_tasks: int
          ) -> Tuple[List[Tuple[str, float, str]], List[Dict]]:
    rows: List[Tuple[str, float, str]] = []
    points: List[Dict] = []
    for controller in CONTROLLERS:
        runner, pred_variant, base_variants = CELLS[controller]
        base: Dict[str, Dict[str, float]] = {}
        for variant in base_variants:
            t0 = time.perf_counter()
            m = runner(variant, 0.0, n_runs, n_tasks)
            us = (time.perf_counter() - t0) / n_runs * 1e6
            base[variant] = m
            rows.append((f"predictor.{controller}.baseline.{variant}", us,
                         derived_str(m)))
            points.append(dict(controller=controller, variant=variant,
                               error=0.0, **m))
        break_error = BREAK_NONE
        for error in errors:
            t0 = time.perf_counter()
            m = runner(pred_variant, error, n_runs, n_tasks)
            us = (time.perf_counter() - t0) / n_runs * 1e6
            tag = f"predictor.{controller}.e{error:g}.{pred_variant}"
            rows.append((tag, us, derived_str(m)))
            points.append(dict(controller=controller, variant=pred_variant,
                               error=error, **m))
            if break_error == BREAK_NONE and not healthy(controller, m, base):
                break_error = error
        rows.append((f"predictor.break.{controller}", 0.0,
                     f"knee={break_error:g}"))
        points.append(dict(controller=controller, variant="break",
                           error=break_error, knee=break_error))
    return rows, points


def run(smoke: bool = False, collect: Optional[Dict] = None
        ) -> List[Tuple[str, float, str]]:
    """Entry point for benchmarks/run.py (full) and --smoke (CI)."""
    if smoke:
        rows, points = sweep(SMOKE_ERRORS, n_runs=1, n_tasks=160)
    else:
        rows, points = sweep(ERRORS, n_runs=3, n_tasks=256)
    if collect is not None:
        collect["points"] = points
    return rows


def showcase_cell(n_tasks: int = 160):
    """EASY backfill threading batch work between interactive arrivals,
    for ``--trace-out``."""
    iso = mean_isolated_time()
    tasks, gap = backfill_workload(iso, seed=9900)
    pol = Backfill(preemptive=False)
    pol.gap_fn = exact_gap_fn(gap, N_INTERACTIVE * gap)
    sim = ClusterSimulator(PAPER_NPU, pol,
                           ClusterConfig(n_devices=1, mechanism="dynamic"))
    return sim, tasks


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (2 error levels, 1 run)")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base every benchmark RNG stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write machine-readable JSON results")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land next to --out")
    common.add_obs_args(ap)
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    extra: Dict = {}
    with common.maybe_profile(args.profile, args.out, "predictor_sweep"):
        rows = run(smoke=args.smoke, collect=extra)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "predictor_sweep", rows, extra=extra)
    common.record_showcase(args, showcase_cell,
                           window=8.0 * mean_isolated_time())


if __name__ == "__main__":
    main()
