"""Observability overhead gate: pay-for-what-you-use, measured.

The obs layer's contract has two halves, both checked here on the
simperf diurnal smoke cell (10k tasks, 16 devices, prema — the same
backlog-building workload the event-core gate runs):

* **detached = free**: with nothing attached the bus keeps its
  no-subscriber fast path — subscriber lists stay empty after
  attach→detach, and the event log is bit-identical to a run where the
  tracer never existed;
* **attached = bounded**: a live :class:`repro.obs.tracing.SpanTracer`
  observes every event without perturbing scheduling (attached event
  log bit-identical to detached) and costs at most
  ``OBS_OVERHEAD_MAX`` extra wall time.  Detached/attached repeats are
  interleaved and the gated ratio (``benchmarks/check_smoke.py``) is
  the *minimum per-repeat paired ratio*: pairs compare adjacent
  instants so machine drift cancels, and since contention noise only
  ever adds wall time the cleanest pair is the closest observable to
  the true overhead; absolute tasks/sec is machine noise, the ratio is
  not.

An informational full-stack row (tracer + telemetry + SLO monitor all
attached) shows the cost of everything at once; only the tracer ratio is
gated.

Usage::

    PYTHONPATH=src python benchmarks/obs_overhead.py --smoke --out o.json
    PYTHONPATH=src python benchmarks/obs_overhead.py --trace-out t.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks import common
from benchmarks.simperf import make_diurnal_tasks

SMOKE_CELL = (10_000, 16, "prema")
FULL_CELLS = ((10_000, 16, "prema"), (100_000, 16, "prema"))
REPEATS = 5
# The attached/detached wall ceiling lives with the other gate
# constants in benchmarks/check_smoke.py (OBS_OVERHEAD_MAX).


def _build(n_dev: int, policy: str, keep_log: bool):
    from repro.core.cluster import ClusterConfig, ClusterSimulator
    from repro.core.scheduler import make_policy
    from repro.hw import PAPER_NPU

    sim = ClusterSimulator(PAPER_NPU, make_policy(policy, True),
                           ClusterConfig(n_devices=n_dev))
    sim.events.keep_log = keep_log
    return sim


def _timed(n: int, n_dev: int, policy: str, seed: int,
           attachers) -> List[List[float]]:
    """Wall seconds per configuration per repeat.  Each ``attachers``
    entry receives the sim and returns a detach callback (or None).
    The configurations run back-to-back *within* each repeat, so a
    paired ratio (attached_r / detached_r) compares adjacent instants
    and machine drift across the whole measurement cancels out."""
    walls: List[List[float]] = [[] for _ in attachers]
    for _ in range(REPEATS):
        for per_cfg, attach in zip(walls, attachers):
            tasks = make_diurnal_tasks(n, n_dev, seed)
            sim = _build(n_dev, policy, keep_log=False)
            detach = attach(sim)
            t0 = time.perf_counter()
            sim.run(tasks)
            per_cfg.append(time.perf_counter() - t0)
            if detach is not None:
                detach()
    return walls


def parity_checks(n: int, n_dev: int, policy: str, seed: int) -> Dict:
    """Bit-parity half of the gate (logs kept, one run each)."""
    from repro.obs import SpanTracer

    logs = {}
    # never-attached baseline
    sim = _build(n_dev, policy, keep_log=True)
    sim.run(make_diurnal_tasks(n, n_dev, seed))
    logs["baseline"] = list(sim.events.log)
    # attach → detach before run: fast path must be restored
    sim = _build(n_dev, policy, keep_log=True)
    tracer = SpanTracer().attach(sim)
    tracer.detach()
    fastpath = all(not subs for subs in sim.events._subs.values())
    sim.run(make_diurnal_tasks(n, n_dev, seed))
    logs["detached"] = list(sim.events.log)
    # attached for the whole run: must observe, never perturb
    sim = _build(n_dev, policy, keep_log=True)
    tracer = SpanTracer().attach(sim)
    sim.run(make_diurnal_tasks(n, n_dev, seed))
    logs["attached"] = list(sim.events.log)
    return {
        "detached_exact": logs["baseline"] == logs["detached"],
        "attached_exact": logs["baseline"] == logs["attached"],
        "fastpath_restored": fastpath,
        "n_events": len(logs["baseline"]),
        "n_spans": len(tracer.spans),
        "tracer": tracer,
    }


def run_cell(n: int, n_dev: int, policy: str, seed: int) -> Dict:
    from repro.obs import SLOMonitor, SLORule, SpanTracer, Telemetry

    def no_obs(sim):
        return None

    def with_tracer(sim):
        tracer = SpanTracer().attach(sim)
        return tracer.detach

    def with_stack(sim):
        tracer = SpanTracer().attach(sim)
        tel = Telemetry().attach(sim)
        slo = SLOMonitor([SLORule(name="hi", target=0.9)]).attach(sim)
        return lambda: (tracer.detach(), tel.detach(), slo.detach())

    det, att, stk = _timed(n, n_dev, policy, seed,
                           (no_obs, with_tracer, with_stack))
    par = parity_checks(n, n_dev, policy, seed)
    return {"n": n, "devices": n_dev, "policy": policy,
            "wall_detached_s": min(det), "wall_attached_s": min(att),
            "wall_stack_s": min(stk),
            # timer noise is one-sided (contention only ever adds wall
            # time), so the cleanest adjacent pair is the closest
            # observable to the true overhead
            "overhead_ratio": min(a / d for a, d in zip(att, det)),
            "stack_ratio": min(s / d for s, d in zip(stk, det)),
            "detached_exact": par["detached_exact"],
            "attached_exact": par["attached_exact"],
            "fastpath_restored": par["fastpath_restored"],
            "n_events": par["n_events"], "n_spans": par["n_spans"],
            "_tracer": par["tracer"]}


def run(smoke: bool = False, seed: int = 0,
        collect: Optional[Dict] = None, trace_out: Optional[str] = None
        ) -> List[Tuple[str, float, str]]:
    cells = (SMOKE_CELL,) if smoke else FULL_CELLS
    rows: List[Tuple[str, float, str]] = []
    results = []
    for n, dev, policy in cells:
        c = run_cell(n, dev, policy, seed)
        tracer = c.pop("_tracer")
        results.append(c)
        tag = f"obs.{policy}.n{n}.d{dev}"
        rows.append((f"{tag}.detached", c["wall_detached_s"] * 1e6,
                     f"tps={n / c['wall_detached_s']:.0f}"))
        rows.append((f"{tag}.attached", c["wall_attached_s"] * 1e6,
                     f"tps={n / c['wall_attached_s']:.0f};"
                     f"ratio={c['overhead_ratio']:.3f}"))
        rows.append((f"{tag}.fullstack", c["wall_stack_s"] * 1e6,
                     f"ratio={c['stack_ratio']:.3f}"))
        rows.append((f"{tag}.parity", 0.0,
                     ("exact" if c["detached_exact"] and c["attached_exact"]
                      and c["fastpath_restored"] else "MISMATCH")
                     + f";n_events={c['n_events']};n_spans={c['n_spans']}"))
        if trace_out and (n, dev, policy) == cells[0]:
            tracer.export(trace_out)
            print(f"perfetto trace written: {trace_out}", file=sys.stderr)
    if collect is not None:
        collect["cells"] = results
        collect["repeats"] = REPEATS
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI cell only (1e4 tasks x 16 devices)")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base the workload RNG stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write machine-readable JSON results")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land next to --out")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the attached parity run as a Perfetto "
                         "trace (the CI artifact)")
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    extra: Dict = {}
    with common.maybe_profile(args.profile, args.out, "obs_overhead"):
        rows = run(smoke=args.smoke, seed=args.seed, collect=extra,
                   trace_out=args.trace_out)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "obs_overhead", rows, extra=extra)


if __name__ == "__main__":
    main()
