"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figures 5/6 (preemption
mechanisms), 11/12 (scheduling policies, static vs dynamic mechanism),
13/14 (SLA + tail latency), 15 (CHECKPOINT vs KILL), prediction accuracy
vs oracle, the §Roofline table derived from the dry-run artifacts, the
multi-NPU cluster-scaling sweep, the offered-load sweep (traffic
subsystem: latency–throughput curves + SLA knee), the overload sweep
(open vs closed loop x admission control x policy past saturation), and
the autoscale sweep (elastic capacity vs static fleets under diurnal and
bursty traffic).

Usage::

    PYTHONPATH=src python benchmarks/run.py [only] [--seed N]

``only`` filters modules by substring; ``--seed`` re-bases every benchmark
RNG stream (the default 0 reproduces the historical hard-coded seeds).
"""
import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` from anywhere, even without
# PYTHONPATH=src: make both `benchmarks` and `repro` importable
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def main() -> None:
    from benchmarks import (autoscale_sweep, cluster_scaling, common,
                            fig5_fig6_mechanisms, fig11_fig12_policies,
                            fig13_fig14_qos, fig15_kill_sensitivity,
                            load_sweep, overload_sweep, pred_accuracy,
                            roofline)
    modules = [
        ("fig5_fig6", fig5_fig6_mechanisms),
        ("fig11_fig12", fig11_fig12_policies),
        ("fig13_fig14", fig13_fig14_qos),
        ("fig15", fig15_kill_sensitivity),
        ("pred_accuracy", pred_accuracy),
        ("roofline", roofline),
        ("cluster_scaling", cluster_scaling),
        ("load_sweep", load_sweep),
        ("overload_sweep", overload_sweep),
        ("autoscale_sweep", autoscale_sweep),
    ]
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("only", nargs="?", default=None,
                    help="run only modules whose name contains this")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base every benchmark RNG stream")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land in "
                         "benchmarks-<module>.pstats per module")
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        with common.maybe_profile(args.profile, None, f"benchmarks-{name}"):
            rows = mod.run()
        wall = (time.perf_counter() - t0) * 1e6
        common.emit(rows)
        print(f"{name}.total,{wall:.0f},ok")


if __name__ == "__main__":
    main()
