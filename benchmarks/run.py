"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figures 5/6 (preemption
mechanisms), 11/12 (scheduling policies, static vs dynamic mechanism),
13/14 (SLA + tail latency), 15 (CHECKPOINT vs KILL), prediction accuracy
vs oracle, plus the §Roofline table derived from the dry-run artifacts.
"""
import sys
import time


def main() -> None:
    from benchmarks import (cluster_scaling, common, fig5_fig6_mechanisms,
                            fig11_fig12_policies, fig13_fig14_qos,
                            fig15_kill_sensitivity, pred_accuracy, roofline)
    modules = [
        ("fig5_fig6", fig5_fig6_mechanisms),
        ("fig11_fig12", fig11_fig12_policies),
        ("fig13_fig14", fig13_fig14_qos),
        ("fig15", fig15_kill_sensitivity),
        ("pred_accuracy", pred_accuracy),
        ("roofline", roofline),
        ("cluster_scaling", cluster_scaling),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in modules:
        if only and only not in name:
            continue
        t0 = time.perf_counter()
        rows = mod.run()
        wall = (time.perf_counter() - t0) * 1e6
        common.emit(rows)
        print(f"{name}.total,{wall:.0f},ok")


if __name__ == "__main__":
    main()
