"""CI sanity + regression gate over the bench JSON artifacts.

``make bench-smoke`` writes one JSON file per benchmark (the ``--out``
contract of ``benchmarks/common.write_json``); this script validates that
the results are not merely present but *shaped like the physics they
claim*:

* every file: parses, has non-empty rows;
* ``cluster_scaling``: the n=1 parity assertion ran (the single-NPU
  simulator and ``ClusterSimulator(n_devices=1)`` agreed bit-exactly);
* ``load_sweep``: the SLA-knee rows exist and parse;
* ``overload_sweep``: closed-loop arrivals demonstrably react to
  congestion — offered throughput self-limits past saturation while the
  open-loop curve keeps climbing and its tail blows up — and with
  admission control enabled PREMA keeps the interactive tenant's SLA
  satisfaction >= 90 % at every swept load;
* ``autoscale_sweep``: on diurnal traffic, autoscaled PREMA holds the
  interactive tenant's SLA >= 90 % while consuming <= 60 % of the
  static-max fleet's device-seconds;
* ``chaos_sweep``: an inert fault injector is bit-identical to no
  injector, checkpoint recovery strictly beats KILL-restart on lost
  work at every swept failure rate, PREMA with crash replacement holds
  the interactive SLA >= 90 % under failures, and client retries keep
  offered == completed + dropped exact;
* ``obs_overhead``: the observability layer pays for what it uses — a
  detached (and an attach-then-detach) run is bit-identical to a run
  where the tracer never existed with the bus's no-subscriber fast path
  restored, an attached tracer observes without perturbing the log, and
  its wall overhead stays <= ``OBS_OVERHEAD_MAX`` (a same-machine ratio;
  against a baseline only the machine-independent event/span counts are
  compared);
* ``predictor_sweep``: with *exact* predictions every predictive
  controller beats its prediction-free baseline — cost-metered admission
  admits more goodput than request counting, the lookahead autoscaler
  holds SLA >= the reactive scaler at <= its device-seconds, EASY
  backfill raises batch throughput over conservative reservation without
  lowering the interactive SLA — and every controller reports the
  injected-error level at which it stops paying for itself;
* ``simperf``: the fast/legacy parity cell is bit-exact, and against a
  baseline the machine-independent fast-over-legacy speedup ratio may
  not regress by more than 35 % (sub-second smoke cells are timer-noisy;
  an absolute floor separately requires fast >= legacy) nor any fast
  cell's peak RSS grow by
  more than 10 % (absolute tasks/sec is machine-dependent and is never
  compared).

With ``--baseline DIR`` the script additionally compares every metric it
can parse out of the rows against the committed baseline JSON of the
same benchmark (``make bench-baseline`` refreshes them) and fails on a
>10 % regression in any SLA/latency/throughput-direction metric — the
``bench-regression`` CI job's contract.  Wall-clock (``us_per_call``)
and direction-neutral counters are never compared.

Exit code 0 = all gates pass.  Usage::

    python benchmarks/check_smoke.py out/*.json
    python benchmarks/check_smoke.py out/*.json --baseline benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, List

GROWTH_MIN_OPEN = 1.2       # open-loop offered rate must scale with load
BACKLOG_RATIO_MIN = 1.5     # open peak backlog vs closed, past saturation
TAIL_BLOWUP_MIN = 2.0       # open-loop FCFS p99 NTT growth past the knee
SLA_HI_MIN = 0.9
AUTOSCALE_CAPACITY_MAX = 0.6   # autoscaled device-seconds vs static-max
CHAOS_LOST_RATIO_MIN = 1.0     # KILL-restart lost work over checkpoint's
OBS_OVERHEAD_MAX = 1.15        # tracer-attached / detached wall ceiling
BATCHING_SPEEDUP_MIN = 1.1     # batched tokens/s over single-slot floor
REGRESSION_TOL = 0.10          # --baseline: relative drift allowed
SIMPERF_SPEEDUP_TOL = 0.35     # simperf: allowed speedup-ratio regression
SIMPERF_SPEEDUP_FLOOR = 1.0    # simperf: fast must never lose to legacy
SIMPERF_RSS_TOL = 0.10         # simperf: allowed peak-RSS growth


class GateError(AssertionError):
    pass


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise GateError(msg)


def load_payload(path: str) -> Dict:
    with open(path) as fp:
        payload = json.load(fp)
    _check(isinstance(payload.get("rows"), list) and payload["rows"],
           f"{path}: empty or missing rows")
    for row in payload["rows"]:
        _check({"name", "us_per_call", "derived"} <= set(row),
               f"{path}: malformed row {row!r}")
    return payload


def check_cluster_scaling(payload: Dict) -> None:
    parity = [r for r in payload["rows"] if "parity" in r["name"]]
    _check(bool(parity), "cluster_scaling: n=1 parity row missing")
    _check(all(r["derived"] == "exact" for r in parity),
           f"cluster_scaling: parity not exact: {parity}")


def check_load_sweep(payload: Dict) -> None:
    knees = [r for r in payload["rows"] if r["name"].endswith(".sla_knee")]
    _check(bool(knees), "load_sweep: SLA-knee rows missing")
    for r in knees:
        _check(r["derived"].startswith("load="),
               f"load_sweep: unparseable knee row {r!r}")


def _points(payload: Dict, **match) -> List[Dict]:
    pts = payload.get("extra", {}).get("points", [])
    return [p for p in pts
            if all(p.get(k) == v for k, v in match.items())]


def check_overload_sweep(payload: Dict) -> None:
    points = payload.get("extra", {}).get("points", [])
    _check(bool(points), "overload_sweep: structured points missing")
    loads = sorted({p["load"] for p in points})
    _check(len(loads) >= 2, f"overload_sweep: need >= 2 loads, got {loads}")
    lo, hi = loads[0], loads[-1]

    for policy in sorted({p["policy"] for p in points}):
        open_lo = _points(payload, mode="open", policy=policy,
                          admission="none", load=lo)
        open_hi = _points(payload, mode="open", policy=policy,
                          admission="none", load=hi)
        closed_lo = _points(payload, mode="closed", policy=policy,
                            admission="none", load=lo)
        closed_hi = _points(payload, mode="closed", policy=policy,
                            admission="none", load=hi)
        if not (open_lo and open_hi and closed_lo and closed_hi):
            continue
        o_lo, o_hi = open_lo[0]["offered_tps"], open_hi[0]["offered_tps"]
        c_lo, c_hi = closed_lo[0]["offered_tps"], closed_hi[0]["offered_tps"]
        _check(o_hi >= o_lo * GROWTH_MIN_OPEN,
               f"overload[{policy}]: open-loop offered rate did not grow "
               f"with load ({o_lo:.2f} -> {o_hi:.2f})")
        # closed clients slow down with the system: their offered rate must
        # grow strictly slower than the open-loop curve ...
        _check(c_hi / max(c_lo, 1e-9) < o_hi / max(o_lo, 1e-9),
               f"overload[{policy}]: closed-loop offered rate did not "
               f"self-limit ({c_lo:.2f} -> {c_hi:.2f} vs open "
               f"{o_lo:.2f} -> {o_hi:.2f})")
        # ... and past saturation the open-loop backlog outgrows the
        # client-bounded closed-loop backlog (the unbounded-queue signature)
        _check(open_hi[0]["peak_backlog"]
               >= BACKLOG_RATIO_MIN * closed_hi[0]["peak_backlog"],
               f"overload[{policy}]: open-loop backlog "
               f"({open_hi[0]['peak_backlog']:.0f}) did not outgrow "
               f"closed-loop ({closed_hi[0]['peak_backlog']:.0f})")

    fcfs_lo = _points(payload, mode="open", policy="fcfs",
                      admission="none", load=lo)
    fcfs_hi = _points(payload, mode="open", policy="fcfs",
                      admission="none", load=hi)
    if fcfs_lo and fcfs_hi:
        _check(fcfs_hi[0]["p99_ntt"] >= fcfs_lo[0]["p99_ntt"] * TAIL_BLOWUP_MIN,
               "overload: open-loop FCFS tail did not blow up past "
               f"saturation ({fcfs_lo[0]['p99_ntt']:.1f} -> "
               f"{fcfs_hi[0]['p99_ntt']:.1f})")

    guarded = [p for p in points if p["policy"] == "prema"
               and p["admission"] != "none" and p["mode"] == "open"]
    _check(bool(guarded), "overload: no prema+admission points")
    for p in guarded:
        _check(p["sla_hi"] >= SLA_HI_MIN,
               f"overload: prema+{p['admission']} interactive SLA "
               f"{p['sla_hi']:.3f} < {SLA_HI_MIN} at load {p['load']}")


def check_autoscale_sweep(payload: Dict) -> None:
    points = payload.get("extra", {}).get("points", [])
    _check(bool(points), "autoscale_sweep: structured points missing")
    head = _points(payload, traffic="diurnal", policy="prema",
                   config="autoscale_vs_staticmax")
    _check(bool(head), "autoscale_sweep: diurnal prema headline missing")
    for p in head:
        _check(p["sla_hi"] >= SLA_HI_MIN,
               f"autoscale: diurnal prema autoscaled interactive SLA "
               f"{p['sla_hi']:.3f} < {SLA_HI_MIN}")
        _check(p["capacity_ratio"] <= AUTOSCALE_CAPACITY_MAX,
               f"autoscale: diurnal prema consumed "
               f"{p['capacity_ratio']:.3f} of static-max device-seconds "
               f"(ceiling {AUTOSCALE_CAPACITY_MAX})")
    static1 = _points(payload, traffic="diurnal", policy="prema",
                      config="static1")
    auto = _points(payload, traffic="diurnal", policy="prema",
                   config="autoscale")
    if static1 and auto:
        _check(auto[0]["sla_hi"] >= static1[0]["sla_hi"],
               "autoscale: scaling up did not improve on the "
               "single-device interactive SLA")


def check_chaos_sweep(payload: Dict) -> None:
    points = payload.get("extra", {}).get("points", [])
    _check(bool(points), "chaos_sweep: structured points missing")
    parity = [r for r in payload["rows"]
              if r["name"] == "chaos.parity.inert_injector"]
    _check(bool(parity), "chaos_sweep: inert-injector parity row missing")
    _check(all(r["derived"] == "exact" for r in parity),
           f"chaos_sweep: inert injector changed the event log: {parity}")
    # checkpoint recovery must strictly beat KILL-restart on lost work
    ratios = [p for p in points if p.get("config") == "kill_vs_checkpoint"]
    _check(bool(ratios), "chaos_sweep: kill-vs-checkpoint headline missing")
    for p in ratios:
        _check(p["lost_ratio"] > CHAOS_LOST_RATIO_MIN,
               f"chaos[{p['level']},{p['policy']}]: KILL-restart lost only "
               f"{p['lost_ratio']:.3f}x checkpoint recovery's work "
               f"(must exceed {CHAOS_LOST_RATIO_MIN})")
    # PREMA + crash replacement holds the interactive SLA under failures
    guarded = [p for p in points if p.get("config") == "replace"
               and p.get("policy") == "prema"
               and p.get("mechanism") == "checkpoint"]
    _check(bool(guarded), "chaos_sweep: prema+replace points missing")
    for p in guarded:
        _check(p["sla_hi"] >= SLA_HI_MIN,
               f"chaos[{p['level']}]: prema+replace interactive SLA "
               f"{p['sla_hi']:.3f} < {SLA_HI_MIN}")
    # failures really happened, and availability accounting stayed sane
    failing = [p for p in points if p.get("fails", 0) > 0]
    _check(bool(failing), "chaos_sweep: no cell saw a failure")
    for p in failing:
        _check(0.0 < p["avail"] < 1.0,
               f"chaos[{p['level']},{p['config']},{p['policy']}]: "
               f"availability {p['avail']:.3f} outside (0, 1) despite "
               f"{p['fails']:.0f} failures")
    # client retries keep logical-task accounting exact
    retry = [p for p in points if p.get("config") == "retry"]
    _check(bool(retry), "chaos_sweep: retry cell missing")
    for p in retry:
        _check(p["exact"] == 1.0,
               f"chaos: retry cell lost tasks (done={p['n_done']:.0f} "
               f"dropped={p['n_dropped']:.0f})")
        _check(p["retries"] > 0, "chaos: retry cell never retried")


def check_batching_sweep(payload: Dict) -> None:
    """The continuous-batching headline gate: at a fixed cluster size
    every multi-slot config must beat the one-request-per-device
    baseline on tokens/s, the chunked-prefill configs must hold the
    interactive TTFT SLA, and the disaggregated pools must actually
    hand sequences across the prefill/decode boundary."""
    points = payload.get("extra", {}).get("points", [])
    _check(bool(points), "batching_sweep: structured points missing")
    by_cfg = {p["config"]: p for p in points}
    _check("single" in by_cfg, "batching_sweep: single-slot baseline missing")
    base_tps = by_cfg["single"]["tokens_per_s"]
    batched = [p for c, p in by_cfg.items() if c != "single"]
    _check(bool(batched), "batching_sweep: no batched configs")
    for p in batched:
        _check(p["tokens_per_s"] >= BATCHING_SPEEDUP_MIN * base_tps,
               f"batching[{p['config']}]: tokens/s "
               f"{p['tokens_per_s']:.0f} did not beat single-slot "
               f"{base_tps:.0f} by >= {BATCHING_SPEEDUP_MIN}x")
    for cfg in ("chunked", "disagg"):
        _check(cfg in by_cfg, f"batching_sweep: {cfg} config missing")
        _check(by_cfg[cfg]["interactive_ttft_sla"] >= SLA_HI_MIN,
               f"batching[{cfg}]: interactive TTFT SLA "
               f"{by_cfg[cfg]['interactive_ttft_sla']:.3f} < {SLA_HI_MIN}")
    _check(by_cfg["disagg"]["migrations"] > 0,
           "batching[disagg]: no prefill->decode KV hand-offs happened")


def check_predictor_sweep(payload: Dict) -> None:
    """The prediction-pays-for-itself gate: at zero injected error each
    predictive controller must beat its prediction-free baseline on its
    headline metric (the autoscaler must *dominate* — SLA and
    device-seconds), and each controller's break row must exist so the
    sweep demonstrably probed where prediction error stops helping."""
    points = payload.get("extra", {}).get("points", [])
    _check(bool(points), "predictor_sweep: structured points missing")

    def one(**match) -> Dict:
        pts = _points(payload, **match)
        _check(bool(pts), f"predictor_sweep: missing point {match}")
        return pts[0]

    adm = one(controller="admission", variant="predicted_cost", error=0.0)
    adm_base = one(controller="admission", variant="token_bucket")
    _check(adm["goodput"] >= adm_base["goodput"],
           f"predictor[admission]: cost-metered goodput {adm['goodput']:.2f}"
           f" lost to request counting {adm_base['goodput']:.2f} at e=0")

    look = one(controller="autoscale", variant="lookahead", error=0.0)
    react = one(controller="autoscale", variant="reactive")
    _check(look["sla_satisfaction"] >= react["sla_satisfaction"],
           f"predictor[autoscale]: lookahead SLA "
           f"{look['sla_satisfaction']:.3f} < reactive "
           f"{react['sla_satisfaction']:.3f} at e=0")
    _check(look["device_seconds"] <= react["device_seconds"],
           f"predictor[autoscale]: lookahead spent "
           f"{look['device_seconds']:.2f} device-seconds > reactive "
           f"{react['device_seconds']:.2f} at e=0")

    bf = one(controller="backfill", variant="backfill", error=0.0)
    reserve = one(controller="backfill", variant="reserve")
    _check(bf["tput_batch"] > reserve["tput_batch"],
           f"predictor[backfill]: EASY batch throughput "
           f"{bf['tput_batch']:.3f} did not beat reservation "
           f"{reserve['tput_batch']:.3f} at e=0")
    _check(bf["sla_hi"] >= reserve["sla_hi"],
           f"predictor[backfill]: EASY interactive SLA {bf['sla_hi']:.3f}"
           f" < reservation {reserve['sla_hi']:.3f} at e=0")

    for controller in ("admission", "autoscale", "backfill"):
        br = one(controller=controller, variant="break")
        _check(br["knee"] > 0.0,
               f"predictor[{controller}]: broken at zero error "
               f"(knee={br['knee']:g})")


def check_simperf(payload: Dict) -> None:
    parity = [r for r in payload["rows"] if ".parity." in r["name"]]
    _check(bool(parity), "simperf: fast-vs-legacy parity row missing")
    _check(all(r["derived"] == "exact" for r in parity),
           f"simperf: fast path diverged from the frozen core: {parity}")
    cells = payload.get("extra", {}).get("cells", [])
    _check(bool(cells), "simperf: structured cells missing")
    for c in cells:
        _check(c.get("tasks_per_sec", 0) > 0 and c.get("peak_rss_mb", 0) > 0,
               f"simperf: degenerate cell {c!r}")
    speedups = payload.get("extra", {}).get("speedups", [])
    _check(bool(speedups), "simperf: no fast/legacy speedup pairs measured")
    for p in speedups:
        _check(p["speedup"] >= SIMPERF_SPEEDUP_FLOOR,
               f"simperf: fast path lost to the frozen core: {p!r}")


def check_obs_overhead(payload: Dict) -> None:
    """The pay-for-what-you-use gate: detached runs are bit-identical
    with the fast path restored, and the tracer-attached wall overhead
    stays under ``OBS_OVERHEAD_MAX`` (a same-machine ratio, not an
    absolute timing)."""
    parity = [r for r in payload["rows"] if r["name"].endswith(".parity")]
    _check(bool(parity), "obs_overhead: parity rows missing")
    _check(all(r["derived"].startswith("exact") for r in parity),
           f"obs_overhead: detached/attached parity broken: {parity}")
    cells = payload.get("extra", {}).get("cells", [])
    _check(bool(cells), "obs_overhead: structured cells missing")
    for c in cells:
        _check(c["overhead_ratio"] <= OBS_OVERHEAD_MAX,
               f"obs_overhead: tracer overhead {c['overhead_ratio']:.3f}x "
               f"> {OBS_OVERHEAD_MAX}x at n={c['n']} d={c['devices']} "
               f"{c['policy']}")
        _check(c["detached_exact"] and c["attached_exact"]
               and c["fastpath_restored"],
               f"obs_overhead: parity flags false in cell {c!r}")
        _check(c["n_spans"] > 0 and c["n_events"] > 0,
               f"obs_overhead: degenerate cell {c!r}")


def compare_obs_overhead_baseline(payload: Dict, base: Dict) -> List[str]:
    """obs_overhead's baseline gate.  Wall ratios are same-machine noise
    across CI runners, so only the machine-independent event/span counts
    are compared — a drift there means the workload or the tracer's
    reconstruction changed."""
    failures: List[str] = []
    key = ("n", "devices", "policy")
    base_cells = {tuple(c[k] for k in key): c
                  for c in base.get("extra", {}).get("cells", [])}
    cur_cells = {tuple(c[k] for k in key): c
                 for c in payload.get("extra", {}).get("cells", [])}
    for k in sorted(base_cells):
        if k not in cur_cells:
            failures.append(f"obs_overhead: cell disappeared: {k}")
            continue
        for field in ("n_events", "n_spans"):
            if cur_cells[k][field] != base_cells[k][field]:
                failures.append(
                    f"obs_overhead: {field} at n={k[0]} d={k[1]} {k[2]} "
                    f"changed: {base_cells[k][field]} -> "
                    f"{cur_cells[k][field]}")
    return failures


def _simperf_cells(payload: Dict) -> Dict[tuple, Dict]:
    return {(c["impl"], c["n"], c["devices"], c["policy"]): c
            for c in payload.get("extra", {}).get("cells", [])}


def compare_simperf_baseline(payload: Dict, base: Dict) -> List[str]:
    """The simperf regression gate.  Absolute tasks/sec depends on the CI
    machine, so the gate compares the fast/legacy speedup *ratio* (both
    implementations measured in the same run on the same machine) and the
    fast cells' peak RSS."""
    failures: List[str] = []
    base_sp = {(p["n"], p["devices"], p["policy"]): p["speedup"]
               for p in base.get("extra", {}).get("speedups", [])}
    cur_sp = {(p["n"], p["devices"], p["policy"]): p["speedup"]
              for p in payload.get("extra", {}).get("speedups", [])}
    for key in sorted(base_sp):
        if key not in cur_sp:
            failures.append(f"simperf: speedup pair disappeared: {key}")
            continue
        floor = base_sp[key] * (1.0 - SIMPERF_SPEEDUP_TOL)
        if cur_sp[key] < floor:
            failures.append(
                f"simperf: speedup at n={key[0]} d={key[1]} {key[2]} "
                f"regressed beyond {SIMPERF_SPEEDUP_TOL:.0%}: "
                f"{base_sp[key]:.2f}x -> {cur_sp[key]:.2f}x")
    cur_cells, base_cells = _simperf_cells(payload), _simperf_cells(base)
    for key in sorted(base_cells):
        if key[0] != "fast":
            continue
        if key not in cur_cells:
            failures.append(f"simperf: cell disappeared: {key}")
            continue
        ceil = base_cells[key]["peak_rss_mb"] * (1.0 + SIMPERF_RSS_TOL)
        if cur_cells[key]["peak_rss_mb"] > ceil:
            failures.append(
                f"simperf: peak RSS at n={key[1]} d={key[2]} {key[3]} "
                f"grew beyond {SIMPERF_RSS_TOL:.0%}: "
                f"{base_cells[key]['peak_rss_mb']:.1f} MB -> "
                f"{cur_cells[key]['peak_rss_mb']:.1f} MB")
    return failures


CHECKS = {
    "cluster_scaling": check_cluster_scaling,
    "load_sweep": check_load_sweep,
    "overload_sweep": check_overload_sweep,
    "autoscale_sweep": check_autoscale_sweep,
    "chaos_sweep": check_chaos_sweep,
    "batching_sweep": check_batching_sweep,
    "predictor_sweep": check_predictor_sweep,
    "simperf": check_simperf,
    "obs_overhead": check_obs_overhead,
}

# Benchmarks whose baseline comparison replaces the generic directional
# metric drift check (their rows carry machine-dependent wall-clock
# readings the generic gate must not compare).
BASELINE_CHECKS = {
    "simperf": compare_simperf_baseline,
    "obs_overhead": compare_obs_overhead_baseline,
}


# ---------------------------------------------------------------------------
# --baseline: metric extraction + directional regression comparison
# ---------------------------------------------------------------------------
# Tokens classifying a metric's direction.  Only the *final* key
# component (the metric name itself) is tokenized on "_"/"@" and matched
# exactly — never the row tag, whose segments ("overload", "load0.8")
# would otherwise leak a direction onto neutral counters and workload
# properties ("offered", "ups", "migrations", ...).  Lower-better wins
# when both match ("sla_viol" carries both "sla" and "viol").
LOWER_BETTER = frozenset(
    ("viol", "p95", "p99", "antt", "tail95", "devsec", "seconds",
     "shed", "backlog", "ckpt", "ratio", "lost"))
HIGHER_BETTER = frozenset(
    ("sla", "stp", "goodput", "tput", "achieved", "util", "throughput",
     "fairness", "load", "knee", "avail", "tok"))


def metric_direction(key: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 not compared."""
    name = key.rsplit(".", 1)[-1]
    tokens = set(name.replace("@", "_").split("_"))
    if tokens & LOWER_BETTER:
        return -1
    if tokens & HIGHER_BETTER:
        return +1
    return 0


def parse_derived(derived: str) -> Dict[str, float]:
    """A row's ``derived`` field as name→value pairs: either one bare
    float, or ``k=v;k=v`` (a trailing ``@...`` qualifier is dropped, so
    the knee rows' ``load=1.6@sla>=0.9`` parses as ``load=1.6``)."""
    body = derived.split("@")[0]
    try:
        return {"": float(body)}
    except ValueError:
        pass
    out: Dict[str, float] = {}
    for part in body.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            continue
    return out


def extract_metrics(payload: Dict) -> Dict[str, float]:
    """Flatten a benchmark payload into comparable ``name[.key]`` → value
    pairs (wall-clock columns are deliberately not extracted)."""
    out: Dict[str, float] = {}
    for row in payload["rows"]:
        for k, v in parse_derived(row["derived"]).items():
            out[row["name"] + ("." + k if k else "")] = v
    return out


def compare_to_baseline(payload: Dict, base: Dict,
                        tol: float = REGRESSION_TOL) -> List[str]:
    """Directional comparison of every parseable metric; returns failure
    messages for >tol regressions (improvements never fail)."""
    cur_m, base_m = extract_metrics(payload), extract_metrics(base)
    failures: List[str] = []
    for key in sorted(base_m):
        direction = metric_direction(key)
        if direction == 0:
            continue
        bval = base_m[key]
        if key not in cur_m:
            failures.append(f"metric disappeared: {key}")
            continue
        cval = cur_m[key]
        if math.isnan(bval) or math.isnan(cval):
            continue
        drift = (cval - bval) / max(abs(bval), 1e-9)
        if direction * drift < -tol:
            arrow = "dropped" if direction > 0 else "grew"
            failures.append(
                f"{key} {arrow} beyond {tol:.0%}: "
                f"{bval:.4g} -> {cval:.4g} ({drift:+.1%})")
    return failures


def baseline_path(payload: Dict, baseline_dir: str) -> str:
    return os.path.join(baseline_dir, payload.get("benchmark", "?") + ".json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("paths", nargs="+", help="bench-smoke JSON files")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="directory of committed baseline JSONs; fail on "
                         f">{REGRESSION_TOL:.0%} SLA/latency regression "
                         "(refresh with `make bench-baseline`)")
    args = ap.parse_args()
    failures = []
    for path in args.paths:
        try:
            payload = load_payload(path)
            name = payload.get("benchmark", "")
            check = CHECKS.get(name)
            if check is None:
                raise GateError(f"{path}: unknown benchmark {name!r}")
            check(payload)
            n_cmp = ""
            if args.baseline:
                bpath = baseline_path(payload, args.baseline)
                try:
                    base = load_payload(bpath)
                except OSError:
                    raise GateError(
                        f"no committed baseline {bpath}; run "
                        "`make bench-baseline` and commit the result"
                    ) from None
                baseline_check = BASELINE_CHECKS.get(name)
                if baseline_check is not None:
                    regressions = baseline_check(payload, base)
                else:
                    regressions = compare_to_baseline(payload, base)
                if regressions:
                    raise GateError("regression vs baseline:\n  " +
                                    "\n  ".join(regressions))
                n_cmp = (f", {len(extract_metrics(base))} baseline "
                         f"metrics within {REGRESSION_TOL:.0%}")
            print(f"ok   {path} ({name}, {len(payload['rows'])} rows{n_cmp})")
        except (GateError, OSError, json.JSONDecodeError) as exc:
            failures.append(f"FAIL {path}: {exc}")
            print(failures[-1])
    if failures:
        sys.exit(1)
    print(f"bench-smoke sanity: {len(args.paths)} file(s) pass")


if __name__ == "__main__":
    main()
