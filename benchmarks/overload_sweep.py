"""Overload sweep: offered load past saturation x admission x policy.

The regime where PREMA's case is strongest: *overload*.  For offered
loads spanning both sides of cluster saturation, this sweep compares

* **open-loop** Poisson arrivals (clients ignore congestion; the queue
  and the tail grow without bound past the knee) against **closed-loop**
  reactive clients (``repro.workloads.ClosedLoop.drive``: each client
  waits for its previous request's actual ``complete``/``drop`` event
  plus a think time, so offered throughput self-limits at saturation);
* **admission control** off vs on (``priority_shed``: shed low-priority
  arrivals while the queue is congested, protecting the interactive
  class) and per-tenant ``token_bucket`` rate limiting (full sweep);
* **fcfs** vs **prema** scheduling.

The workload is a three-tenant mix over the paper's 8-DNN suite —
``interactive`` (priority 9, tight 4x SLA), ``standard`` (3, 8x), and
``batch`` (1, loose 20x) — so shedding and scheduling decisions have an
SLA-visible victim and beneficiary.  Every run is observed through the
shared event stream (``core/events.py``): offered/achieved throughput and
shed rate are counted from submit/complete/drop events, latency and SLA
metrics from the completed tasks.

Per point: offered and achieved throughput (tasks/s), shed rate, SLA
satisfaction of admitted work (overall and for the interactive tenant),
and p99 NTT/turnaround.  Per curve: the SLA knee (max load with >= 90 %
satisfaction of admitted work).

Usage::

    PYTHONPATH=src python benchmarks/overload_sweep.py            # full
    PYTHONPATH=src python benchmarks/overload_sweep.py --smoke    # CI
    PYTHONPATH=src python benchmarks/overload_sweep.py --out o.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# allow `python benchmarks/overload_sweep.py` from anywhere (same pattern
# as cluster_scaling): make both `benchmarks` and `repro` importable
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks import common
from benchmarks.load_sweep import SLA_KNEE_TARGET, find_knee
from repro.configs import paper_workloads as pw
from repro.core import metrics
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.scheduler import make_policy
from repro.hw import PAPER_NPU
from repro.workloads import (ClosedLoop, Poisson, TenantSpec, TrafficMix,
                             generate, make_admission)

MODES = ("open", "closed")
POLICIES = ("fcfs", "prema")
ADMISSIONS = ("none", "priority_shed")
ADMISSIONS_FULL = ("none", "priority_shed", "token_bucket")
LOADS = (0.6, 0.9, 1.2, 1.6, 2.0)
TASKS_PER_DEVICE = 24
HI_TENANT = "interactive"

_mean_isolated: Dict[int, float] = {}    # keyed by BASE_SEED


def tenant_mix(arrivals) -> TrafficMix:
    """Three SLA classes over the paper suite: the shedding/scheduling
    trade-off needs a protected class and a sheddable one."""
    models = tuple(pw.WORKLOAD_NAMES)
    return TrafficMix(tenants=(
        TenantSpec(name=HI_TENANT, models=models, share=0.25, priority=9,
                   sla_scale=4.0),
        TenantSpec(name="standard", models=models, share=0.375, priority=3,
                   sla_scale=8.0),
        TenantSpec(name="batch", models=models, share=0.375, priority=1,
                   sla_scale=20.0),
    ), arrivals=arrivals, kind="paper")


def mean_isolated_time(n_probe: int = 64) -> float:
    key = common.BASE_SEED
    if key not in _mean_isolated:
        tr = generate(tenant_mix(Poisson(rate=1.0)), common.rng(8700),
                      n_probe, pred=common.predictor())
        _mean_isolated[key] = float(
            np.mean([t.isolated_time for t in tr.tasks()]))
    return _mean_isolated[key]


def make_admission_policy(name: str, n_devices: int):
    if name == "none":
        return None
    if name == "priority_shed":
        return make_admission("priority_shed", soft_depth=4 * n_devices,
                              hard_depth=16 * n_devices)
    if name == "token_bucket":
        # cap each tenant near its fair share of cluster capacity
        return make_admission("token_bucket",
                              rate=0.5 * n_devices / mean_isolated_time(),
                              burst=4.0)
    raise KeyError(f"unknown admission config {name!r}")


def run_point(mode: str, policy: str, admission: str, n_devices: int,
              load: float, n_tasks: int, n_runs: int, seed0: int = 8800
              ) -> Dict[str, float]:
    """One (mode, policy, admission, load) cell, averaged over runs."""
    rate = load * n_devices / mean_isolated_time()
    runs = []
    for r in range(n_runs):
        rng = common.rng(seed0 + 131 * r)
        tr = generate(tenant_mix(Poisson(rate=rate)), rng, n_tasks,
                      pred=common.predictor())
        sim = ClusterSimulator(
            PAPER_NPU, make_policy(policy, preemptive=True),
            ClusterConfig(mechanism="dynamic", n_devices=n_devices,
                          placement="least_loaded",
                          admission=make_admission_policy(
                              admission, n_devices)))
        if mode == "closed":
            think = mean_isolated_time()
            n_clients = max(1, int(round(rate * 2.0 * think)))
            proc = ClosedLoop(n_clients=n_clients, think_time=think)
            tasks = proc.drive(sim, tr.tasks(), seed=seed0 + r)
        else:
            tasks = sim.run(tr)

        log = sim.events.log
        makespan = max(ev.t for ev in log)
        n_submit = sum(1 for ev in log if ev.kind == "submit")
        n_drop = sum(1 for ev in log if ev.kind == "drop")
        n_complete = sum(1 for ev in log if ev.kind == "complete")
        # peak in-flight work: the queue-growth signature (bounded by the
        # client count in a closed system, unbounded open-loop past 1.0)
        backlog, peak_backlog = 0, 0
        for ev in log:
            if ev.kind == "submit":
                backlog += 1
                peak_backlog = max(peak_backlog, backlog)
            elif ev.kind in ("complete", "drop"):
                backlog -= 1
        m = sim.summary()
        per_tenant = metrics.per_tenant_summary(tasks)
        hi = per_tenant.get(HI_TENANT, {})
        runs.append({
            "offered_tps": n_submit / max(makespan, 1e-12),
            "achieved_tps": n_complete / max(makespan, 1e-12),
            "peak_backlog": float(peak_backlog),
            "shed_rate": n_drop / max(n_submit, 1),
            "sla_satisfaction": m["sla_satisfaction"],
            "sla_hi": float(hi.get("sla_satisfaction", float("nan"))),
            "shed_hi": float(hi.get("shed_rate", 0.0)),
            "p99_ntt": m["p99_ntt"],
            "p99_turnaround": m["p99_turnaround"],
            "goodput": m["goodput"],
        })
    return metrics.aggregate(runs)


def sweep(modes: Sequence[str], policies: Sequence[str],
          admissions: Sequence[str], loads: Sequence[float],
          n_devices: int, n_runs: int,
          tasks_per_device: int = TASKS_PER_DEVICE
          ) -> Tuple[List[Tuple[str, float, str]], List[Dict]]:
    rows: List[Tuple[str, float, str]] = []
    points: List[Dict] = []
    for mode in modes:
        for pol in policies:
            for adm in admissions:
                curve = []
                for load in loads:
                    t0 = time.perf_counter()
                    m = run_point(mode, pol, adm, n_devices, load,
                                  n_tasks=tasks_per_device * n_devices,
                                  n_runs=n_runs)
                    us = (time.perf_counter() - t0) / n_runs * 1e6
                    curve.append((load, m))
                    tag = (f"overload.{mode}.{pol}.{adm}."
                           f"d{n_devices}.load{load:g}")
                    rows.append((tag, us, (
                        f"offered={m['offered_tps']:.1f};"
                        f"achieved={m['achieved_tps']:.1f};"
                        f"backlog={m['peak_backlog']:.0f};"
                        f"shed={m['shed_rate']:.3f};"
                        f"sla={m['sla_satisfaction']:.3f};"
                        f"sla_hi={m['sla_hi']:.3f};"
                        f"p99_ntt={m['p99_ntt']:.2f}")))
                    points.append(dict(mode=mode, policy=pol, admission=adm,
                                       n_devices=n_devices, load=load, **m))
                knee = find_knee(curve)
                rows.append((f"overload.{mode}.{pol}.{adm}."
                             f"d{n_devices}.sla_knee", 0.0,
                             f"load={knee:g}@sla>={SLA_KNEE_TARGET}"))
    return rows, points


def run(smoke: bool = False,
        collect: Optional[Dict] = None) -> List[Tuple[str, float, str]]:
    """Entry point for benchmarks/run.py (full) and --smoke (CI).  When
    ``collect`` is given, the structured per-point results land in
    ``collect['points']`` (the ``--out`` JSON extra payload)."""
    if smoke:
        rows, points = sweep(MODES, POLICIES, ADMISSIONS,
                             loads=(0.8, 1.6), n_devices=1, n_runs=1,
                             tasks_per_device=24)
    else:
        rows, points = sweep(MODES, POLICIES, ADMISSIONS_FULL, LOADS,
                             n_devices=2, n_runs=3)
    if collect is not None:
        collect["points"] = points
    return rows


def showcase_cell(n_devices: int = 2, load: float = 1.6):
    """Past-saturation prema + priority_shed, for ``--trace-out`` — a
    preemption/shedding storm timeline."""
    rate = load * n_devices / mean_isolated_time()
    tr = generate(tenant_mix(Poisson(rate=rate)), common.rng(8800),
                  TASKS_PER_DEVICE * n_devices, pred=common.predictor())
    sim = ClusterSimulator(
        PAPER_NPU, make_policy("prema", preemptive=True),
        ClusterConfig(mechanism="dynamic", n_devices=n_devices,
                      admission=make_admission_policy("priority_shed",
                                                      n_devices)))
    return sim, tr.tasks()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (2 loads, 1 run per point)")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base every benchmark RNG stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write machine-readable JSON results")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land next to --out")
    common.add_obs_args(ap)
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    extra: Dict = {}
    with common.maybe_profile(args.profile, args.out, "overload_sweep"):
        rows = run(smoke=args.smoke, collect=extra)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "overload_sweep", rows, extra=extra)
    common.record_showcase(args, showcase_cell,
                           window=2.0 * mean_isolated_time())


if __name__ == "__main__":
    main()
