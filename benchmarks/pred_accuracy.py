"""§VI-A / §VI-D: prediction-model accuracy and PREMA-vs-oracle gap.

The oracle scheduler sees each task's *actual* execution time; PREMA sees
only the Algorithm-1 + LUT prediction.  The paper reports 98% correlation
and 99% of oracle STP/ANTT/SLA.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks import common
from repro.configs import paper_workloads as pw
from repro.core import metrics, trace
from repro.core.scheduler import make_policy
from repro.core.simulator import NPUSimulator, SimConfig
from repro.hw import PAPER_NPU


def run() -> List:
    pred = common.predictor()
    rng = common.rng(99)
    preds, actuals = [], []
    for i in range(500):
        name = str(rng.choice(pw.WORKLOAD_NAMES))
        t = trace.make_task(i, name, pred, rng, arrival=0.0)
        preds.append(t.predicted_total)
        actuals.append(t.isolated_time)
    corr = float(np.corrcoef(preds, actuals)[0, 1])
    mape = float(np.mean(np.abs(np.array(preds) - np.array(actuals))
                         / np.array(actuals)))

    # oracle: same workloads, predicted_total := actual
    ws = common.workloads()
    m_pred, m_oracle = [], []
    for tasks in ws:
        m_pred.append(metrics.summarize(
            common.run_policy(tasks, "prema", True, "dynamic")))
        oracle_tasks = trace.clone_tasks(tasks)
        for t in oracle_tasks:
            t.predicted_total = t.isolated_time
        sim = NPUSimulator(PAPER_NPU, make_policy("prema", True),
                           SimConfig(mechanism="dynamic"))
        m_oracle.append(metrics.summarize(sim.run(oracle_tasks)))
    p = metrics.aggregate(m_pred)
    o = metrics.aggregate(m_oracle)
    return [
        ("pred.correlation", 0.0, f"{corr:.4f}"),
        ("pred.mean_abs_pct_error", 0.0, f"{mape*100:.2f}%"),
        ("pred.stp_of_oracle", 0.0, f"{p['stp']/o['stp']:.4f}"),
        ("pred.antt_of_oracle", 0.0, f"{o['antt']/p['antt']:.4f}"),
        ("pred.sla4_of_oracle", 0.0,
         f"{(1-p['sla_viol@4'])/max(1e-9, 1-o['sla_viol@4']):.4f}"),
    ]
