"""Continuous-batching sweep: one-request-per-device vs batch slots,
chunked prefill, and prefill/decode disaggregation.

The serving engine's headline trade (docs/benchmarks.md): at a *fixed*
cluster size, continuous batching multiplies decode throughput — an
iteration with ``B`` co-resident requests costs
``(1 + batch_overhead*(B-1)) * max(step_i)``, so tokens/s scales nearly
linearly in ``B`` on decode-bound work — while chunked prefill and
disaggregated pools protect the *interactive* TTFT SLO from long-prompt
batch jobs that would otherwise stall shared iterations.

Four configurations over the same mixed workload (interactive priority-9
short prompts + priority-1 long-prompt batch jobs, Poisson arrivals past
the single-slot saturation point) on the same 4-device cluster:

* ``single``   — ``batch_slots=1``: the classic one-request-per-device
  loop (the seed engine's behavior; parity-locked).
* ``batched``  — 8 slots per device, *monolithic* prefill: each prompt
  runs as one blocking step, so a long prefill stalls its co-residents.
* ``chunked``  — 8 slots + chunked prefill: prompts advance one period
  per iteration and decode latency stays bounded.
* ``disagg``   — chunked + a dedicated prefill pool (1 prefill / 3
  decode devices, ``speed_aware`` placement): prefill never shares an
  iteration with decode at all; sequences migrate KV at hand-off.

Per point: tokens/s, mean/p95 TTFT (overall and interactive-only),
interactive TTFT SLA attainment against an absolute target, mean TPOT,
and KV hand-off migrations.  CI gates (benchmarks/check_smoke.py):
every batched config must beat ``single`` on tokens/s, and the chunked
configs must hold interactive TTFT SLA >= 0.9.

Usage::

    PYTHONPATH=src python benchmarks/batching_sweep.py            # full
    PYTHONPATH=src python benchmarks/batching_sweep.py --smoke    # CI
    PYTHONPATH=src python benchmarks/batching_sweep.py --out o.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks import common
from repro.core import metrics

N_DEVICES = 4
BATCH_SLOTS = 8
MODEL = "olmo-1b"
INTERACTIVE_PRIORITY = 9
# absolute interactive TTFT SLO (seconds of engine virtual time): a few
# interactive prefills' worth of headroom on the tiny profile, tight
# enough that a monolithic long-prompt prefill sharing the iteration
# blows it
TTFT_SLA = 2e-4
TASKS_PER_DEVICE = 30
LOAD = 3.0          # offered load relative to single-slot capacity

CONFIGS: Tuple[Tuple[str, Dict], ...] = (
    ("single", dict(batch_slots=1)),
    ("batched", dict(batch_slots=BATCH_SLOTS, chunked_prefill=False)),
    ("chunked", dict(batch_slots=BATCH_SLOTS, chunked_prefill=True)),
    ("disagg", dict(batch_slots=BATCH_SLOTS, chunked_prefill=True,
                    device_roles=("prefill", "prefill", "decode", "decode"),
                    placement="speed_aware")),
)

_models = None


def models():
    """Tiny registered model shared by every config (params built once)."""
    global _models
    if _models is None:
        import jax
        from repro.models import get_model
        m = get_model(MODEL, tiny=True)
        _models = {MODEL: (m, m.init_params(jax.random.PRNGKey(0)))}
    return _models


def make_requests(rng: np.random.Generator, n: int, rate: float):
    """Mixed open-loop workload: 40% interactive (short prompt, priority
    9), 60% batch (long prompt, priority 1), Poisson arrivals."""
    from repro.serving.request import InferenceRequest
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        interactive = rng.random() < 0.4
        if interactive:
            plen = int(rng.integers(16, 64))
            dec = int(rng.integers(8, 32))
            prio, tenant = INTERACTIVE_PRIORITY, "interactive"
        else:
            plen = int(rng.integers(256, 1024))
            dec = int(rng.integers(64, 256))
            prio, tenant = 1, "batch"
        reqs.append(InferenceRequest(
            rid=i, arch=MODEL,
            prompt=rng.integers(1, 200, (1, plen)).astype(np.int32),
            max_new_tokens=dec, true_decode_len=dec,
            priority=prio, arrival=t, tenant=tenant))
    return reqs


def make_engine(cfg: Dict):
    from repro.serving.engine import EngineConfig, ServingEngine
    kw = dict(execute=False, n_devices=N_DEVICES, policy="prema",
              mechanism="dynamic")
    kw.update(cfg)
    return ServingEngine(models(), cfg=EngineConfig(**kw))


def _probe_rate(n_probe: int = 64) -> float:
    """Arrival rate offering ``LOAD`` x the single-slot cluster capacity
    (requests/s over mean isolated time)."""
    eng = make_engine(dict(batch_slots=1))
    reqs = make_requests(common.rng(9100), n_probe, rate=1.0)
    iso = [eng._make_job(r).task.isolated_time for r in reqs]
    return LOAD * N_DEVICES / float(np.mean(iso))


def run_point(cfg: Dict, n_tasks: int, n_runs: int,
              seed0: int = 9200) -> Dict[str, float]:
    rate = _probe_rate()
    runs = []
    for r in range(n_runs):
        eng = make_engine(cfg)
        reqs = make_requests(common.rng(seed0 + 131 * r), n_tasks, rate)
        results = eng.run(reqs)
        s = metrics.serving_summary(results,
                                    interactive_priority=INTERACTIVE_PRIORITY)
        inter = [x.ttft for x in results
                 if x.priority >= INTERACTIVE_PRIORITY]
        sla_hi = (float(np.mean([t <= TTFT_SLA for t in inter]))
                  if inter else float("nan"))
        runs.append({
            "tokens_per_s": s["tokens_per_s"],
            "mean_ttft": s["mean_ttft"],
            "p95_ttft": s["p95_ttft"],
            "mean_tpot": s["mean_tpot"],
            "interactive_p95_ttft": s["p95_interactive_ttft"],
            "interactive_ttft_sla": sla_hi,
            "migrations": float(eng.cluster.n_migrations),
        })
    return metrics.aggregate(runs)


def sweep(n_tasks: int, n_runs: int
          ) -> Tuple[List[Tuple[str, float, str]], List[Dict]]:
    rows: List[Tuple[str, float, str]] = []
    points: List[Dict] = []
    for label, cfg in CONFIGS:
        t0 = time.perf_counter()
        m = run_point(cfg, n_tasks, n_runs)
        us = (time.perf_counter() - t0) / n_runs * 1e6
        rows.append((f"batching.{label}.d{N_DEVICES}", us, (
            f"tok_s={m['tokens_per_s']:.0f};"
            f"ttft_p95={m['p95_ttft']:.2e};"
            f"int_ttft_p95={m['interactive_p95_ttft']:.2e};"
            f"int_sla={m['interactive_ttft_sla']:.3f};"
            f"tpot={m['mean_tpot']:.2e};"
            f"migr={m['migrations']:.0f}")))
        points.append(dict(config=label, n_devices=N_DEVICES,
                           ttft_sla_target=TTFT_SLA, **m))
    return rows, points


def run(smoke: bool = False,
        collect: Optional[Dict] = None) -> List[Tuple[str, float, str]]:
    """Entry point for benchmarks/run.py (full) and --smoke (CI)."""
    if smoke:
        rows, points = sweep(n_tasks=TASKS_PER_DEVICE * N_DEVICES, n_runs=1)
    else:
        rows, points = sweep(n_tasks=2 * TASKS_PER_DEVICE * N_DEVICES,
                             n_runs=3)
    if collect is not None:
        collect["points"] = points
    return rows


def showcase_cell():
    """The disagg cell for ``--trace-out``: slot sub-tracks on the decode
    pool, KV hand-off migrations from the prefill device."""
    label, cfg = CONFIGS[-1]
    eng = make_engine(cfg)
    reqs = make_requests(common.rng(9200), TASKS_PER_DEVICE * N_DEVICES,
                         _probe_rate())
    tasks = [eng._make_job(r).task for r in reqs]
    del tasks  # Telemetry needs the engine's own job tasks; tracer-only
    return eng, reqs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="single-run sweep for CI")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base every benchmark RNG stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write machine-readable JSON results")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land next to --out")
    common.add_obs_args(ap)
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    extra: Dict = {}
    with common.maybe_profile(args.profile, args.out, "batching_sweep"):
        rows = run(smoke=args.smoke, collect=extra)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "batching_sweep", rows, extra=extra)
    common.record_showcase(args, showcase_cell, window=1e-3)


if __name__ == "__main__":
    main()
