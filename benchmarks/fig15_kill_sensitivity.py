"""Fig 15: CHECKPOINT vs KILL sensitivity across preemptive policies."""
from __future__ import annotations

from typing import List

from benchmarks import common


def run() -> List:
    res = common.sweep([
        ("fcfs", "fcfs", False, "drain"),
        ("hpf_ckpt", "hpf", True, "checkpoint"),
        ("hpf_kill", "hpf", True, "kill"),
        ("token_ckpt", "token", True, "checkpoint"),
        ("token_kill", "token", True, "kill"),
        ("sjf_ckpt", "sjf", True, "checkpoint"),
        ("sjf_kill", "sjf", True, "kill"),
        ("prema_ckpt", "prema", True, "checkpoint"),
        ("prema_kill", "prema", True, "kill"),
    ])
    base = res["fcfs"]
    rows = []
    for label, m in res.items():
        if label == "fcfs":
            continue
        rows.append((f"fig15.{label}", m["us_per_call"],
                     f"antt_x={base['antt']/m['antt']:.2f};"
                     f"fairness_x={m['fairness']/base['fairness']:.2f};"
                     f"stp_x={m['stp']/base['stp']:.2f}"))
    # aggregate checkpoint-vs-kill ratios (paper: ckpt wins on STP)
    for met in ("antt", "stp", "fairness"):
        c = sum(res[f"{p}_ckpt"][met] for p in ("hpf", "token", "sjf", "prema"))
        k = sum(res[f"{p}_kill"][met] for p in ("hpf", "token", "sjf", "prema"))
        better = c / k if met != "antt" else k / c
        rows.append((f"fig15.ckpt_over_kill.{met}", 0.0, f"{better:.3f}"))
    return rows
