"""Fig 11 (non-preemptive policies) + Fig 12 (preemptive, static vs dynamic
mechanism selection).  All numbers normalized to NP-FCFS, as in the paper.
"""
from __future__ import annotations

import time
from typing import List

from benchmarks import common


def run() -> List:
    t0 = time.perf_counter()
    res = common.sweep([
        ("fcfs", "fcfs", False, "drain"),
        ("rrb", "rrb", False, "drain"),
        ("hpf", "hpf", False, "drain"),
        ("token", "token", False, "drain"),
        ("sjf", "sjf", False, "drain"),
        ("prema", "prema", False, "drain"),
        ("hpf_p_static", "hpf", True, "checkpoint"),
        ("token_p_static", "token", True, "checkpoint"),
        ("sjf_p_static", "sjf", True, "checkpoint"),
        ("prema_p_static", "prema", True, "checkpoint"),
        ("hpf_p_dyn", "hpf", True, "dynamic"),
        ("token_p_dyn", "token", True, "dynamic"),
        ("sjf_p_dyn", "sjf", True, "dynamic"),
        ("prema_p_dyn", "prema", True, "dynamic"),
    ])
    base = res["fcfs"]
    rows = []
    for label, m in res.items():
        fig = "fig11" if "_p_" not in label else "fig12"
        rows.append((f"{fig}.{label}", m["us_per_call"],
                     f"antt_x={base['antt']/m['antt']:.2f};"
                     f"fairness_x={m['fairness']/base['fairness']:.2f};"
                     f"stp_x={m['stp']/base['stp']:.2f}"))
    _ = time.perf_counter() - t0
    return rows
