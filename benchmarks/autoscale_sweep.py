"""Autoscale sweep: elastic capacity vs static fleets under time-varying load.

PREMA's economic case assumes the fleet rides demand: cloud DNN traffic
is diurnal and bursty, so a fixed-size cluster is either over-provisioned
(paying for idle accelerators at night) or under-provisioned (blowing the
interactive SLA at peak).  This sweep drives the cluster simulator with
the traffic subsystem's non-stationary processes and compares three
capacity configurations at identical offered load:

* ``static1``     one device, always on (the paper's setting);
* ``staticmax``   ``MAX_DEVICES`` devices, always on (peak-provisioned);
* ``autoscale``   start at one device; ``core/autoscaler.py`` subscribes
  to the event bus and scales between 1 and ``MAX_DEVICES`` off the
  queue-depth signal (devices pay a provision delay on the way up and
  drain-migrate their residents on the way down);
* ``hetero``      ``MAX_DEVICES`` devices but half of them at half clock,
  with speed-aware placement (heterogeneous baseline, not gated).

Traffic is the three-tenant SLA mix of the overload sweep (interactive /
standard / batch) under ``diurnal`` (sinusoidal rate, trace starts at the
trough so scale-up is observable) and ``mmpp`` (bursty on-off) arrivals.

Per point: interactive-tenant SLA satisfaction, overall SLA, p99 NTT,
consumed device-seconds (``capacity_seconds`` — per-device alive time,
the cost denominator), scale-event counts, and mean utilization.  The
headline gate (checked by ``benchmarks/check_smoke.py``): on diurnal
traffic, autoscaled PREMA holds interactive SLA >= 90 % while consuming
<= 60 % of the static-max configuration's device-seconds.

Usage::

    PYTHONPATH=src python benchmarks/autoscale_sweep.py            # full
    PYTHONPATH=src python benchmarks/autoscale_sweep.py --smoke    # CI
    PYTHONPATH=src python benchmarks/autoscale_sweep.py --out a.json
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

# allow `python benchmarks/autoscale_sweep.py` from anywhere (same
# pattern as cluster_scaling): make `benchmarks` and `repro` importable
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks import common
from benchmarks.overload_sweep import HI_TENANT, mean_isolated_time, tenant_mix
from repro.core import metrics
from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.cluster import ClusterConfig, ClusterSimulator
from repro.core.scheduler import make_policy
from repro.hw import PAPER_NPU
from repro.workloads import MMPP, Diurnal, generate

TRAFFICS = ("diurnal", "mmpp")
CONFIGS = ("static1", "staticmax", "autoscale", "hetero")
POLICIES = ("fcfs", "prema")
MAX_DEVICES = 4
AVG_LOAD = 1.8          # mean offered load, in single-device capacities
TASKS_PER_RUN = 192
# The SLA floor / device-seconds ceiling the headline is gated on live in
# benchmarks/check_smoke.py (SLA_HI_MIN, AUTOSCALE_CAPACITY_MAX).

# Half-clock variant of the paper NPU for the heterogeneous baseline.
SLOW_NPU = dataclasses.replace(
    PAPER_NPU, name="paper-npu-half", freq_hz=PAPER_NPU.freq_hz / 2
)


def make_traffic(kind: str, rate: float, period: float):
    if kind == "diurnal":
        # amplitude 0.85: peak ~ 1.85x mean, trough ~ 0.15x; phase 0.75
        # starts the trace at the trough, so the autoscaler must both
        # grow into the morning ramp and shrink back after the peak
        return Diurnal(base_rate=rate, amplitude=0.85, period=period, phase=0.75)
    if kind == "mmpp":
        return MMPP.bursty(rate, duty=0.3)
    raise KeyError(f"unknown traffic kind {kind!r}")


def make_sim(config: str, policy: str) -> Tuple[ClusterSimulator, Optional[Autoscaler]]:
    iso = mean_isolated_time()
    base = dict(mechanism="dynamic")
    if config == "static1":
        cfg = ClusterConfig(n_devices=1, **base)
    elif config == "staticmax":
        cfg = ClusterConfig(n_devices=MAX_DEVICES, **base)
    elif config == "hetero":
        half = MAX_DEVICES // 2
        cfg = ClusterConfig(
            device_hw=[PAPER_NPU] * (MAX_DEVICES - half) + [SLOW_NPU] * half,
            placement="speed_aware",
            **base,
        )
    elif config == "autoscale":
        cfg = ClusterConfig(n_devices=1, provision_latency=0.5 * iso, **base)
    else:
        raise KeyError(f"unknown config {config!r}")
    sim = ClusterSimulator(PAPER_NPU, make_policy(policy, preemptive=True), cfg)
    scaler = None
    if config == "autoscale":
        scaler = Autoscaler(
            AutoscalerConfig(
                min_devices=1,
                max_devices=MAX_DEVICES,
                target_queue_per_device=2.0,
                low_watermark=0.35,
                window=3.0 * iso,
                cooldown=1.5 * iso,
            )
        ).attach(sim)
    return sim, scaler


def run_point(
    traffic: str, config: str, policy: str, n_runs: int, n_tasks: int, seed0: int = 9100
) -> Dict[str, float]:
    iso = mean_isolated_time()
    rate = AVG_LOAD / iso
    period = 64.0 * iso
    runs = []
    for r in range(n_runs):
        rng = common.rng(seed0 + 211 * r)
        tr = generate(
            tenant_mix(make_traffic(traffic, rate, period)),
            rng,
            n_tasks,
            pred=common.predictor(),
        )
        sim, scaler = make_sim(config, policy)
        tasks = sim.run(tr)
        m = sim.summary()
        hi = metrics.per_tenant_summary(tasks).get(HI_TENANT, {})
        runs.append(
            {
                "sla_satisfaction": m["sla_satisfaction"],
                "sla_hi": float(hi.get("sla_satisfaction", float("nan"))),
                "p99_ntt": m["p99_ntt"],
                "device_seconds": m["capacity_seconds"],
                "makespan": m["makespan"],
                "util_mean": m["util_mean"],
                "n_scale_ups": m["n_scale_ups"],
                "n_scale_downs": m["n_scale_downs"],
                "migrations": m["migrations"],
                "goodput": m["goodput"],
            }
        )
        if scaler is not None:
            scaler.detach()
    return metrics.aggregate(runs)


def sweep(
    traffics: Sequence[str],
    configs: Sequence[str],
    policies: Sequence[str],
    n_runs: int,
    n_tasks: int,
) -> Tuple[List[Tuple[str, float, str]], List[Dict]]:
    rows: List[Tuple[str, float, str]] = []
    points: List[Dict] = []
    cells: Dict[Tuple[str, str, str], Dict[str, float]] = {}
    for traffic in traffics:
        for config in configs:
            for policy in policies:
                t0 = time.perf_counter()
                m = run_point(traffic, config, policy, n_runs, n_tasks)
                us = (time.perf_counter() - t0) / n_runs * 1e6
                cells[(traffic, config, policy)] = m
                tag = f"autoscale.{traffic}.{config}.{policy}"
                rows.append(
                    (
                        tag,
                        us,
                        f"sla_hi={m['sla_hi']:.3f};"
                        f"sla={m['sla_satisfaction']:.3f};"
                        f"p99_ntt={m['p99_ntt']:.2f};"
                        f"devsec={m['device_seconds']:.4f};"
                        f"ups={m['n_scale_ups']:.1f};"
                        f"downs={m['n_scale_downs']:.1f}",
                    )
                )
                points.append(
                    dict(traffic=traffic, config=config, policy=policy, **m)
                )
    # headline: autoscaled capacity cost relative to peak provisioning
    for traffic in traffics:
        for policy in policies:
            auto = cells.get((traffic, "autoscale", policy))
            peak = cells.get((traffic, "staticmax", policy))
            if auto is None or peak is None:
                continue
            ratio = auto["device_seconds"] / max(peak["device_seconds"], 1e-12)
            rows.append(
                (
                    f"autoscale.{traffic}.{policy}.capacity_vs_staticmax",
                    0.0,
                    f"ratio={ratio:.3f};sla_hi={auto['sla_hi']:.3f}",
                )
            )
            points.append(
                dict(
                    traffic=traffic,
                    config="autoscale_vs_staticmax",
                    policy=policy,
                    capacity_ratio=ratio,
                    sla_hi=auto["sla_hi"],
                )
            )
    return rows, points


def run(
    smoke: bool = False, collect: Optional[Dict] = None
) -> List[Tuple[str, float, str]]:
    """Entry point for benchmarks/run.py (full) and --smoke (CI).  When
    ``collect`` is given, the structured per-point results land in
    ``collect['points']`` (the ``--out`` JSON extra payload)."""
    if smoke:
        rows, points = sweep(
            TRAFFICS, CONFIGS, POLICIES, n_runs=1, n_tasks=TASKS_PER_RUN
        )
    else:
        rows, points = sweep(
            TRAFFICS, CONFIGS, POLICIES, n_runs=3, n_tasks=2 * TASKS_PER_RUN
        )
    if collect is not None:
        collect["points"] = points
    return rows


def showcase_cell(n_tasks: int = TASKS_PER_RUN):
    """Autoscaled prema on the diurnal ramp, for ``--trace-out`` —
    device_up/down tracks alongside the queue-depth counter."""
    iso = mean_isolated_time()
    tr = generate(tenant_mix(make_traffic("diurnal", AVG_LOAD / iso,
                                          64.0 * iso)),
                  common.rng(9100), n_tasks, pred=common.predictor())
    sim, _scaler = make_sim("autoscale", "prema")
    return sim, tr.tasks()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "--smoke", action="store_true", help="tiny sweep for CI (1 run per point)"
    )
    ap.add_argument(
        "--seed", type=int, default=0, help="re-base every benchmark RNG stream"
    )
    ap.add_argument(
        "--out",
        default=None,
        metavar="PATH",
        help="also write machine-readable JSON results",
    )
    ap.add_argument(
        "--profile", action="store_true",
        help="run under cProfile; stats land next to --out"
    )
    common.add_obs_args(ap)
    args = ap.parse_args()
    common.set_seed(args.seed)
    print("name,us_per_call,derived")
    extra: Dict = {}
    with common.maybe_profile(args.profile, args.out, "autoscale_sweep"):
        rows = run(smoke=args.smoke, collect=extra)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "autoscale_sweep", rows, extra=extra)
    common.record_showcase(args, showcase_cell,
                           window=4.0 * mean_isolated_time())


if __name__ == "__main__":
    main()
