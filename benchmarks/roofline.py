"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run's compiled artifacts (results/dryrun.json).

    compute term    = flops / (chips x 197 TFLOP/s bf16)
    memory term     = bytes / (chips x 819 GB/s HBM)
    collective term = collective bytes / (chips x 4 links x 50 GB/s)

flops/collective bytes are the trip-count-corrected per-device numbers
(launch/hlo_analysis.py); the memory term uses XLA 'bytes accessed'
(per-device, loop bodies counted once) *plus* a floor of
(argument+output bytes) — weights/caches are read at least once.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.hw import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

ICI_LINKS = 4
DRYRUN_JSON = os.environ.get("DRYRUN_JSON", "results/dryrun.json")


def roofline_terms(cell: Dict) -> Dict[str, float]:
    flops = cell["flops_per_device"]
    mem = cell["memory"]
    bytes_dev = max(cell["bytes_per_device_raw"],
                    mem["argument"] + mem["output"])
    coll = cell["collective_bytes_per_device"]
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_dev / HBM_BW
    t_x = coll / (ICI_LINKS * ICI_BW_PER_LINK)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    total = max(t_c, t_m, t_x)
    n = cell["n_chips"]
    mf = cell.get("model_flops_global", 0.0) / n
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[1],
        "step_s": total,
        "roofline_frac": (t_c / total) if total > 0 else 0.0,
        "model_flops_ratio": (mf / flops) if flops else 0.0,
        "mfu": (mf / total / PEAK_FLOPS_BF16) if total > 0 else 0.0,
    }


def load(path: str = DRYRUN_JSON) -> Dict[str, Dict]:
    with open(path) as f:
        return json.load(f)


def table(path: str = DRYRUN_JSON, mesh: str = "single") -> List[Dict]:
    rows = []
    for key, cell in sorted(load(path).items()):
        if cell.get("status") != "ok" or cell["mesh"] != mesh:
            continue
        r = {"arch": cell["arch"], "shape": cell["shape"], **roofline_terms(cell)}
        rows.append(r)
    return rows


def run() -> List:
    out = []
    try:
        rows = table()
    except FileNotFoundError:
        return [("roofline.missing", 0.0, "run repro.launch.dryrun first")]
    for r in rows:
        out.append((
            f"roofline.{r['arch']}.{r['shape']}", 0.0,
            f"compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"dominant={r['dominant']};mfu={r['mfu']*100:.1f}%"))
    return out
