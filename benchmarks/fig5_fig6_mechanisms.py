"""Fig 5 + Fig 6: effect of the preemption mechanism in isolation.

Methodology (§IV-D): two-task workloads where a low-priority task runs and
a high-priority task preempts it at a uniform-random point, under P-HPF;
CHECKPOINT / KILL / DRAIN compared on (a) preemption latency, (b) the
preempting task's wait time, (c) STP and (d) preempting-task NTT
improvement over NP-FCFS, as a function of the preempted/preempting model
and batch size.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common
from repro.configs import paper_workloads as pw
from repro.core import metrics, trace
from repro.core.scheduler import make_policy
from repro.core.simulator import NPUSimulator, SimConfig
from repro.hw import PAPER_NPU


def _two_task_runs(mechanism: str, n_runs: int = 60):
    pred = common.predictor()
    rows = []
    for s in range(n_runs):
        rng = common.rng(2000 + s)
        lo_model = str(rng.choice(pw.WORKLOAD_NAMES))
        hi_model = str(rng.choice(pw.WORKLOAD_NAMES))
        lo = trace.make_task(0, lo_model, pred, rng, arrival=0.0, priority=1)
        # preemption point uniform over the low task's execution
        t_pre = float(rng.uniform(0.05, 0.95)) * lo.isolated_time
        hi = trace.make_task(1, hi_model, pred, rng, arrival=t_pre,
                             priority=9)
        done = NPUSimulator(
            PAPER_NPU, make_policy("hpf", preemptive=True),
            SimConfig(mechanism=mechanism)).run([lo, hi])
        lo_d = next(t for t in done if t.tid == 0)
        hi_d = next(t for t in done if t.tid == 1)
        # NP-FCFS reference for the same pair
        lo2 = trace.clone_tasks([lo, hi])
        ref = NPUSimulator(PAPER_NPU, make_policy("fcfs", False),
                           SimConfig(mechanism="drain")).run(lo2)
        hi_ref = next(t for t in ref if t.tid == 1)
        rows.append({
            "preempted": lo_d.model, "preempting": hi_d.model,
            "batch": hi_d.batch,
            "preempt_latency": lo_d.checkpoint_overhead / max(
                lo_d.n_preemptions + lo_d.n_kills, 1),
            "wait": (hi_d.first_service or hi_d.arrival) - hi_d.arrival,
            "stp": metrics.stp(done),
            "ntt_impr": hi_ref.ntt / hi_d.ntt,
        })
    return rows


def run() -> List:
    out = []
    t0 = time.perf_counter()
    for mech in ("checkpoint", "kill", "drain"):
        rows = _two_task_runs(mech)
        lat = np.mean([r["preempt_latency"] for r in rows])
        wait = np.mean([r["wait"] for r in rows])
        stp = np.mean([r["stp"] for r in rows])
        ntt = np.mean([r["ntt_impr"] for r in rows])
        out.append((f"fig5.preempt_latency_us.{mech}", 0.0,
                    f"{lat*1e6:.2f}"))
        out.append((f"fig5.wait_ms.{mech}", 0.0, f"{wait*1e3:.3f}"))
        out.append((f"fig6.stp.{mech}", 0.0, f"{stp:.3f}"))
        out.append((f"fig6.ntt_improvement.{mech}", 0.0, f"{ntt:.2f}"))
    us = (time.perf_counter() - t0) * 1e6 / 3
    return [(n, us if i % 4 == 0 else 0.0, d)
            for i, (n, _, d) in enumerate(out)]
