"""Fig 13 (SLA violation rate vs target N) + Fig 14 (95%-ile tail latency
of high-priority tasks, batch size 1)."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks import common
from repro.core import metrics, trace


def run() -> List:
    t0 = time.perf_counter()
    res = common.sweep([
        ("fcfs", "fcfs", False, "drain"),
        ("sjf_p", "sjf", True, "dynamic"),
        ("prema_p", "prema", True, "dynamic"),
    ])
    rows = []
    for label, m in res.items():
        sla = ";".join(f"N{n}={m[f'sla_viol@{n}']:.3f}"
                       for n in (2, 4, 8, 12, 16, 20))
        rows.append((f"fig13.sla_violation.{label}", m["us_per_call"], sla))

    # Fig 14: single-batch workloads, tail of high-priority NTT
    pred = common.predictor()
    tails = {"fcfs": [], "sjf_p": [], "prema_p": []}
    for s in range(common.N_RUNS):
        rng = common.rng(3000 + s)
        tasks = [trace.make_task(i, str(rng.choice(
            ("CNN-AN", "CNN-GN", "CNN-VN", "CNN-MN", "RNN-SA", "RNN-MT1",
             "RNN-MT2", "RNN-ASR"))), pred, rng,
            arrival=0.0, batch=1) for i in range(common.N_TASKS)]
        total = sum(t.isolated_time for t in tasks)
        for t in tasks:
            t.arrival = float(rng.uniform(0, 0.5 * total))
            t.last_wake = t.arrival
        for label, pol, prem, mech in [("fcfs", "fcfs", False, "drain"),
                                       ("sjf_p", "sjf", True, "dynamic"),
                                       ("prema_p", "prema", True, "dynamic")]:
            done = common.run_policy(tasks, pol, prem, mech)
            v = metrics.tail_latency_ratio(done)
            if np.isfinite(v):
                tails[label].append(v)
    for label, vals in tails.items():
        rows.append((f"fig14.tail95_high_priority.{label}", 0.0,
                     f"x_isolated={np.mean(vals):.2f};max={np.max(vals):.2f}"))
    _ = time.perf_counter() - t0
    return rows
