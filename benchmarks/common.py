"""Shared infrastructure for the figure-reproduction benchmarks.

All benchmarks run on the paper's Table-I NPU model with the paper's
8-DNN suite and methodology (§III): N tasks sampled uniformly over the
suite, uniform-random dispatch, priorities ∈ {1,3,9}, batch ∈ {1,4,16},
averaged over ``N_RUNS`` workloads per configuration.

The CLI contract every benchmark speaks (``--smoke`` / ``--seed`` /
``--out`` / ``--profile``, ``name,us_per_call,derived`` rows,
``write_json`` payloads validated by ``benchmarks/check_smoke.py``) and
the committed-baseline workflow are documented in docs/benchmarks.md.
"""
from __future__ import annotations

import contextlib
import cProfile
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import metrics, trace
from repro.core.predictor import Predictor
from repro.core.scheduler import make_policy
from repro.core.simulator import NPUSimulator, SimConfig
from repro.hw import PAPER_NPU

N_RUNS = 25
N_TASKS = 8

# Global seed offset: 0 reproduces the historical hard-coded streams; the
# ``--seed`` CLI flag (benchmarks/run.py and every standalone entry point)
# shifts every benchmark RNG through set_seed().
BASE_SEED = 0

_predictor: Optional[Predictor] = None


def set_seed(seed: int) -> None:
    """Re-base every benchmark RNG stream (and the profiled LUTs)."""
    global BASE_SEED, _predictor
    BASE_SEED = int(seed)
    _predictor = None          # regressors are profiled under the new seed


def rng(offset: int) -> np.random.Generator:
    """The benchmark RNG contract: streams are keyed by (BASE_SEED, offset)
    so runs are reproducible and --seed moves every stream at once."""
    return np.random.default_rng(BASE_SEED + offset)


def predictor() -> Predictor:
    global _predictor
    if _predictor is None:
        _predictor = Predictor(PAPER_NPU)
        trace.build_regressors(_predictor, rng(1234))
    return _predictor


def workloads(n_runs: int = N_RUNS, n_tasks: int = N_TASKS):
    pred = predictor()
    return [trace.make_workload(pred, rng(1000 + s), n_tasks=n_tasks)
            for s in range(n_runs)]


def run_policy(tasks, policy: str, preemptive: bool, mechanism: str):
    sim = NPUSimulator(PAPER_NPU, make_policy(policy, preemptive),
                       SimConfig(mechanism=mechanism))
    return sim.run(trace.clone_tasks(tasks))


def sweep(configs: List[Tuple[str, str, bool, str]],
          n_runs: int = N_RUNS) -> Dict[str, Dict[str, float]]:
    """configs: (label, policy, preemptive, mechanism).  Returns label →
    averaged metric dict (plus wall-clock us per simulation)."""
    ws = workloads(n_runs)
    out = {}
    for label, pol, prem, mech in configs:
        runs, t0 = [], time.perf_counter()
        for tasks in ws:
            runs.append(metrics.summarize(run_policy(tasks, pol, prem, mech)))
        wall = (time.perf_counter() - t0) / len(ws) * 1e6
        agg = metrics.aggregate(runs)
        agg["us_per_call"] = wall
        out[label] = agg
    return out


@contextlib.contextmanager
def maybe_profile(enabled: bool, out: Optional[str], benchmark: str,
                  tag: Optional[str] = None):
    """The ``--profile`` contract shared by run.py and every standalone
    entry point: when enabled, the wrapped block runs under cProfile and
    the stats land next to ``--out`` (``<out-stem>.pstats``), or as
    ``<benchmark>-seed<S>[-<tag>].pstats`` in the working directory when
    no ``--out`` was given — the seed (and any caller-supplied config
    ``tag``) in the stem keeps two runs of the same benchmark from
    silently overwriting each other.  Inspect with ``python -m pstats``
    or snakeviz."""
    if not enabled:
        yield
        return
    prof = cProfile.Profile()
    prof.enable()
    try:
        yield
    finally:
        prof.disable()
        if out:
            path = os.path.splitext(os.path.abspath(out))[0] + ".pstats"
        else:
            stem = f"{benchmark}-seed{BASE_SEED}"
            if tag:
                stem += f"-{tag}"
            path = f"{stem}.pstats"
        prof.dump_stats(path)
        print(f"profile written: {path}", file=sys.stderr)


def add_obs_args(parser) -> None:
    """The ``--trace-out`` / ``--telemetry-out`` contract shared by every
    sweep: record the sweep's designated showcase cell with a
    :class:`repro.obs.tracing.SpanTracer` (Chrome/Perfetto JSON to
    ``--trace-out``) and/or a :class:`repro.obs.telemetry.Telemetry`
    (JSONL timeseries to ``--telemetry-out``, rendered by
    ``benchmarks/report.py --telemetry``)."""
    parser.add_argument("--trace-out", default=None, metavar="PATH",
                        help="export a Perfetto trace of the showcase "
                             "cell (opens in ui.perfetto.dev)")
    parser.add_argument("--telemetry-out", default=None, metavar="PATH",
                        help="export windowed telemetry JSONL of the "
                             "showcase cell")


@contextlib.contextmanager
def observed(trace_out: Optional[str], telemetry_out: Optional[str],
             layer, tasks=None, window: float = 60.0):
    """Attach obs sinks to ``layer`` for one run and export on exit.
    Both paths None ⇒ nothing is subscribed (the no-subscriber fast path
    is untouched)."""
    from repro.obs import SpanTracer, Telemetry, TelemetryConfig
    tracer = SpanTracer().attach(layer) if trace_out else None
    tel = (Telemetry(TelemetryConfig(window=window)).attach(
        layer, tasks=tasks) if telemetry_out else None)
    try:
        yield
    finally:
        if tracer is not None:
            tracer.detach()
            tracer.export(trace_out)
            print(f"perfetto trace written: {trace_out}", file=sys.stderr)
        if tel is not None:
            tel.detach()
            tel.export_jsonl(telemetry_out)
            print(f"telemetry written: {telemetry_out}", file=sys.stderr)


def record_showcase(args, make_layer_and_tasks, window: float = 60.0) -> None:
    """Run each sweep's designated showcase cell once with obs sinks
    attached when ``--trace-out``/``--telemetry-out`` was given (a
    *separate* run from the measured sweep, so attaching never perturbs
    timings).  ``make_layer_and_tasks() -> (layer, tasks)``."""
    trace_out = getattr(args, "trace_out", None)
    telemetry_out = getattr(args, "telemetry_out", None)
    if not (trace_out or telemetry_out):
        return
    layer, tasks = make_layer_and_tasks()
    with observed(trace_out, telemetry_out, layer, tasks=tasks,
                  window=window):
        layer.run(tasks)


def emit(rows: List[Tuple[str, float, str]]):
    """Print the ``name,us_per_call,derived`` CSV contract."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def write_json(path: str, benchmark: str, rows: List[Tuple[str, float, str]],
               extra: Optional[Dict] = None) -> None:
    """Machine-readable benchmark output (the ``--out`` contract): the CSV
    rows as structured records plus an optional ``extra`` payload of
    benchmark-specific structured results.  Consumed by
    ``benchmarks/check_smoke.py`` in CI."""
    payload = {
        "benchmark": benchmark,
        "base_seed": BASE_SEED,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
        "extra": extra or {},
    }
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as fp:
        json.dump(payload, fp, indent=2, sort_keys=True)
        fp.write("\n")
