"""Event-core throughput benchmark: simulated-tasks/sec and peak RSS.

This is the performance gate for the million-task event core: it measures
the cluster simulator's *simulation throughput* (completed simulated
tasks per wall-clock second) and peak RSS over a matrix of trace sizes,
device counts, and policies, on a **diurnal** workload — piecewise-
constant arrival rate cycling trough → overload peak → trough, the shape
that builds real backlog.  Sustained backlog is exactly where the
historical list-scanning core went quadratic (every wake-up rescanned the
whole ready queue), so each cell also runs the frozen pre-rewrite
implementation (``repro.core._legacy_cluster``) where that is affordable
and reports the machine-independent **speedup ratio** fast/legacy that
``benchmarks/check_smoke.py`` gates on (absolute tasks/sec varies with CI
hardware; the ratio does not).

Every cell runs in its own subprocess so ``ru_maxrss`` is a true per-cell
peak; timing cells run with ``EventBus.keep_log=False`` (the streaming
configuration: peak RSS stays flat in event count).  A parity cell runs
both implementations on one trace in a single process and asserts the
event logs and per-task metrics are **bit-identical** — the same contract
tests/test_fastpath_parity.py fuzzes.

Workload note: tasks are synthetic 8-template DNNs (shared per-template
node arrays).  The event core never looks inside layers — scheduling cost
depends only on queue depth and event count — so templates keep task
construction out of the measurement without changing what is measured.

Usage::

    PYTHONPATH=src python benchmarks/simperf.py --smoke --out simperf.json
    PYTHONPATH=src python benchmarks/simperf.py            # full matrix
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time
from typing import Dict, List, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402

from benchmarks import common  # noqa: E402

# Diurnal profile: load multiplier per segment of each cycle (mean ~1.0,
# overload peak 1.6x capacity).  Each segment gets an equal share of the
# trace's tasks at its own Poisson rate.
DIURNAL_PROFILE = (0.4, 0.6, 1.0, 1.4, 1.6, 1.4, 1.0, 0.6)
N_CYCLES = 4

N_TEMPLATES = 8
NODES_PER_TASK = 6

# (n_tasks, n_devices) cells per implementation.  The legacy core is
# quadratic under backlog, so it only runs where that stays affordable:
# the 1e5x16 cell is the headline speedup measurement; 1e6 would take
# hours and adds nothing the ratio has not already shown.
FULL_FAST_CELLS = ((10_000, 1), (10_000, 16), (10_000, 100),
                   (100_000, 16), (100_000, 100), (1_000_000, 100))
FULL_LEGACY_CELLS = ((10_000, 16), (10_000, 100), (100_000, 16))
SMOKE_FAST_CELLS = ((10_000, 16),)
SMOKE_LEGACY_CELLS = ((10_000, 16),)
POLICIES = ("fcfs", "prema")
PARITY_CELL = (2_000, 4, "prema")


def make_diurnal_tasks(n: int, n_dev: int, seed: int) -> List:
    """n tasks over N_CYCLES diurnal cycles; per-template node arrays
    (and the derived cumulative-progress array) are shared across all
    tasks of a template, so a million-task trace costs per-task Python
    objects only, not per-task numpy arrays."""
    from repro.core.task import Task

    rng = np.random.default_rng(seed)
    node_times = [np.full(NODES_PER_TASK, (1.0 + i) * 1e-3 / NODES_PER_TASK)
                  for i in range(N_TEMPLATES)]
    out_bytes = np.full(NODES_PER_TASK, 1 << 18, dtype=np.int64)
    cums = [np.concatenate([[0.0], np.cumsum(nt)]) for nt in node_times]
    totals = [float(nt.sum()) for nt in node_times]
    mean_svc = float(np.mean(totals))

    loads = np.tile(np.asarray(DIURNAL_PROFILE), N_CYCLES)
    per_seg = max(1, n // len(loads))
    arr_segs, t = [], 0.0
    for ld in loads:
        rate = n_dev / mean_svc * ld
        seg = t + np.cumsum(rng.exponential(1.0 / rate, per_seg))
        arr_segs.append(seg)
        t = seg[-1]
    arrivals = np.concatenate(arr_segs)[:n]
    tidx = rng.integers(0, N_TEMPLATES, len(arrivals))
    prio = rng.choice([1, 3, 9], len(arrivals))
    tasks = []
    for i in range(len(arrivals)):
        k = int(tidx[i])
        task = Task(tid=i, model=f"m{k}", batch=1,
                    arrival=float(arrivals[i]), priority=int(prio[i]),
                    node_times=node_times[k], node_out_bytes=out_bytes,
                    predicted_total=totals[k] * 1.05)
        task._cum = cums[k]      # drop the per-task copy __post_init__ built
        tasks.append(task)
    return tasks


def _build(impl: str, policy: str, n_dev: int):
    from repro.core.cluster import ClusterConfig, ClusterSimulator
    from repro.core.scheduler import make_policy
    from repro.core._legacy_cluster import LegacyClusterSimulator
    from repro.hw import PAPER_NPU

    cfg = ClusterConfig(n_devices=n_dev)
    if impl == "fast":
        return ClusterSimulator(PAPER_NPU, make_policy(policy, True), cfg)
    if impl == "legacy":
        return LegacyClusterSimulator(PAPER_NPU, policy, cfg,
                                      preemptive=True)
    raise ValueError(f"unknown impl {impl!r}")


def run_cell(impl: str, n: int, n_dev: int, policy: str, seed: int) -> Dict:
    """One timing measurement (meant to run in a fresh subprocess so
    ru_maxrss is this cell's own peak).  Streaming configuration: the
    event log is off, as a million-task caller would run it."""
    tasks = make_diurnal_tasks(n, n_dev, seed)
    sim = _build(impl, policy, n_dev)
    sim.events.keep_log = False
    t0 = time.perf_counter()
    done = sim.run(tasks)
    wall = time.perf_counter() - t0
    rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return {"impl": impl, "n": n, "devices": n_dev, "policy": policy,
            "wall_s": wall, "tasks_per_sec": len(done) / wall,
            "peak_rss_mb": rss_kb / 1024.0, "n_tasks": len(done)}


def run_parity(n: int, n_dev: int, policy: str, seed: int) -> Dict:
    """Fast vs frozen-legacy on one trace: event logs and per-task
    metrics must match bit-for-bit."""
    def fingerprint(tasks):
        return [(t.tid, t.state.name, t.completion, t.executed, t.tokens,
                 t.n_preemptions, t.n_kills, t.checkpoint_overhead)
                for t in tasks]

    runs = {}
    for impl in ("fast", "legacy"):
        sim = _build(impl, policy, n_dev)
        done = sim.run(make_diurnal_tasks(n, n_dev, seed))
        runs[impl] = (fingerprint(done), list(sim.events.log))
    exact = runs["fast"] == runs["legacy"]
    return {"kind": "parity", "n": n, "devices": n_dev, "policy": policy,
            "exact": exact, "n_events": len(runs["fast"][1])}


# ---------------------------------------------------------------------------
# Orchestration: one subprocess per cell
# ---------------------------------------------------------------------------

def _spawn(spec_args: List[str], seed: int) -> Dict:
    cmd = [sys.executable, os.path.abspath(__file__),
           "--seed", str(seed)] + spec_args
    proc = subprocess.run(cmd, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"simperf cell {spec_args} failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run(smoke: bool = False, seed: int = 0,
        collect: Optional[Dict] = None) -> List[Tuple[str, float, str]]:
    fast_cells = SMOKE_FAST_CELLS if smoke else FULL_FAST_CELLS
    legacy_cells = SMOKE_LEGACY_CELLS if smoke else FULL_LEGACY_CELLS
    cells: List[Dict] = []
    rows: List[Tuple[str, float, str]] = []
    for policy in POLICIES:
        for n, dev in fast_cells:
            cells.append(_spawn(
                ["--cell", f"fast:{n}:{dev}:{policy}"], seed))
        for n, dev in legacy_cells:
            cells.append(_spawn(
                ["--cell", f"legacy:{n}:{dev}:{policy}"], seed))
    by_key = {(c["impl"], c["n"], c["devices"], c["policy"]): c
              for c in cells}
    for c in cells:
        rows.append((
            f"simperf.{c['policy']}.n{c['n']}.d{c['devices']}.{c['impl']}",
            c["wall_s"] * 1e6,
            f"tps={c['tasks_per_sec']:.0f};rss_mb={c['peak_rss_mb']:.1f}"))
    # machine-independent speedups for every (n, dev, policy) with both
    # implementations measured in this same run
    pairs = []
    for (impl, n, dev, pol), c in sorted(by_key.items()):
        if impl != "fast" or ("legacy", n, dev, pol) not in by_key:
            continue
        leg = by_key[("legacy", n, dev, pol)]
        ratio = c["tasks_per_sec"] / leg["tasks_per_sec"]
        pairs.append({"n": n, "devices": dev, "policy": pol,
                      "speedup": ratio})
        rows.append((f"simperf.{pol}.n{n}.d{dev}.speedup", 0.0,
                     f"speedup={ratio:.2f}"))
    pn, pdev, ppol = PARITY_CELL
    par = _spawn(["--parity-cell", f"{pn}:{pdev}:{ppol}"], seed)
    rows.append((f"simperf.parity.n{pn}.d{pdev}.{ppol}", 0.0,
                 "exact" if par["exact"] else "MISMATCH"))
    if collect is not None:
        collect["cells"] = cells
        collect["speedups"] = pairs
        collect["parity"] = par
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset (1e4 tasks x 16 devices)")
    ap.add_argument("--seed", type=int, default=0,
                    help="re-base the workload RNG stream")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write machine-readable JSON results")
    ap.add_argument("--profile", action="store_true",
                    help="run under cProfile; stats land next to --out")
    ap.add_argument("--cell", default=None, metavar="IMPL:N:DEV:POLICY",
                    help=argparse.SUPPRESS)     # subprocess entry
    ap.add_argument("--parity-cell", default=None, metavar="N:DEV:POLICY",
                    help=argparse.SUPPRESS)     # subprocess entry
    args = ap.parse_args()
    common.set_seed(args.seed)
    if args.cell:
        impl, n, dev, policy = args.cell.split(":")
        print(json.dumps(run_cell(impl, int(n), int(dev), policy,
                                  args.seed)))
        return
    if args.parity_cell:
        n, dev, policy = args.parity_cell.split(":")
        print(json.dumps(run_parity(int(n), int(dev), policy, args.seed)))
        return
    print("name,us_per_call,derived")
    extra: Dict = {}
    with common.maybe_profile(args.profile, args.out, "simperf"):
        rows = run(smoke=args.smoke, seed=args.seed, collect=extra)
    common.emit(rows)
    if args.out:
        common.write_json(args.out, "simperf", rows, extra=extra)


if __name__ == "__main__":
    main()
