"""Relative-link checker for the repo's markdown docs.

Walks the markdown files (and/or directories of them) given on the
command line, extracts every inline link and image
(``[text](target)``), and verifies that each *relative* target resolves
to an existing file or directory relative to the file that links it.
Anchors (``#section``), absolute URLs (``http(s)://``, ``mailto:``),
and bare in-page fragments are skipped — this is a filesystem check,
not a web crawler.

Exit status: 0 when every relative link resolves, 1 otherwise (each
broken link is printed as ``file:line: target``).  Wired into
``make docs-check`` and the CI docs job, so a doc rename that orphans a
link fails the build.

Usage::

    python tools/check_links.py README.md docs
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from typing import Iterator, List, Tuple

# Inline markdown links/images: [text](target) / ![alt](target).
# Reference-style definitions ([name]: target) are rare here; the inline
# pattern covers everything the repo's docs actually use.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

SKIP_PREFIXES = ("http://", "https://", "mailto:", "ftp://", "#")


def iter_markdown(paths: List[str]) -> Iterator[str]:
    """Expand files/directories into the markdown files they contain."""
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for fn in sorted(files):
                    if fn.endswith((".md", ".markdown")):
                        yield os.path.join(root, fn)
        else:
            yield p


def check_file(path: str) -> List[Tuple[str, int, str]]:
    """Broken relative links in one file as (file, line, target) rows."""
    broken = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as fp:
        for lineno, line in enumerate(fp, 1):
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(SKIP_PREFIXES):
                    continue
                # strip an in-page anchor from a file target
                target = target.split("#", 1)[0]
                if not target:
                    continue
                if not os.path.exists(os.path.join(base, target)):
                    broken.append((path, lineno, m.group(1)))
    return broken


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("paths", nargs="+",
                    help="markdown files or directories to walk")
    args = ap.parse_args(argv)
    files = list(iter_markdown(args.paths))
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    broken = [b for f in files for b in check_file(f)]
    for path, lineno, target in broken:
        print(f"{path}:{lineno}: broken relative link -> {target}")
    print(f"check_links: {len(files)} files, "
          f"{len(broken)} broken relative links")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
